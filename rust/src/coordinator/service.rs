//! The analysis service: a sharded multi-client job queue over the NATSA
//! engine.
//!
//! The accelerator itself computes one profile at a time per PU fleet; a
//! deployment wraps it in a service that accepts jobs from many clients,
//! applies backpressure when queues fill, and reports metrics — the same
//! role the vLLM router plays for model replicas.  The paper's flagship
//! workloads (arrhythmia review, seismic monitoring) are *many concurrent
//! streams*, and the journal extension of NATSA (arXiv 2206.00938) scales
//! the design across multiple accelerator stacks; this service mirrors
//! that shape with **engine shards**:
//!
//! * each shard owns a bounded queue, a worker pool, and a slice of the
//!   PU fleet ([`crate::natsa::NatsaConfig::shard_slice`] — 48 PUs over
//!   4 shards model 4 stacks of 12 PUs; a non-dividing count deals the
//!   remainder to the first shards, so no PU is lost);
//! * a **stream** is placed on one shard at
//!   [`AnalysisService::submit_stream`] (hash of the stream id) and
//!   routed through the epoch-versioned table in
//!   [`crate::coordinator::router`] from then on — its
//!   inherently-sequential appends can only ever park workers of its
//!   *current* home shard; a client pipelining appends head-of-line
//!   blocks that one shard at worst, never the fleet (the old
//!   single-queue service parked every worker in turn-waiting).  The
//!   shard index packed into the id's low bits is only the mint-time
//!   **hint**: hot-shard migration
//!   ([`crate::coordinator::migrate`], [`AnalysisService::migrate_stream`])
//!   can move the stream, and only the router is authoritative;
//! * **batch** jobs go to the least-loaded shard at submit time and spill
//!   to the next shard when its queue is full, so they flow around a
//!   stream storm instead of queueing behind it.
//!
//! Two job kinds share each shard's queue:
//!
//! * **batch** — [`AnalysisService::submit`]: one series, one profile.
//! * **stream** — [`AnalysisService::submit_stream`] opens a long-lived
//!   [`StreamSession`]; [`AnalysisService::append_stream`] enqueues sample
//!   batches against it (same bounded queue, same backpressure) and each
//!   append's [`JobResult`] carries the post-append profile snapshot;
//!   [`AnalysisService::snapshot_stream`] reads the live profile without
//!   queueing.  Appends to one stream are applied in submission order
//!   even across workers (per-stream sequence numbers), so a stream's
//!   profile is always that of its samples in arrival order.  A whole
//!   sample batch is applied as blocked multi-row tiles of the unified
//!   row kernel ([`StreamSession::extend`] →
//!   `mp::kernel::compute_row_n`), so feeding packets through the
//!   service rides the same SIMD hot path as the batch fleet; the
//!   engine's live profile is kept in the kernel's squared-distance
//!   representation and each snapshot (the append result's profile,
//!   `snapshot_stream`) finalizes it with one deferred sqrt pass.
//!
//! ## Cross-stream coalescing & snapshot fanout
//!
//! A fleet of concurrent *single-append* streams used to execute one
//! width-1 row tile per append — forfeiting exactly the multi-lane fill
//! the engine's blocked path wins (`BENCH_streaming.json`).  Each worker
//! therefore **drains its shard queue opportunistically**: after the
//! blocking receive it `try_recv`s up to [`ServiceConfig::coalesce`]
//! more queued jobs, picks out the single-sample appends whose streams
//! agree on `(m, excl)` and whose turn has come (checked with
//! `try_lock` only — a worker never blocks while holding another
//! stream's lock), and applies them as **one shared multi-lane row
//! tile** ([`crate::natsa::append_group`] →
//! `mp::kernel::compute_row_group`).  Every member's slot is then
//! completed individually, per-stream ordering is preserved (a member
//! is only grouped when it *is* the stream's next turn; everything
//! else — multi-sample packets, not-ready or key-mismatched appends,
//! batch jobs — runs on the unchanged serial path afterwards, in drain
//! order), and each member's resulting state is **bit-identical** to
//! the isolated append path.  The WAL shape is unchanged (one `Append`
//! record per member, logged before the tile), so crash recovery
//! replays to the same bits.  [`ServiceMetrics::coalesce_width`] /
//! [`ServiceMetrics::appends_coalesced`] report how wide the steady
//! state actually rides.
//!
//! **Snapshot fanout** serves the popular-stream shape (one producer,
//! N watchers) without multiplying kernel work by N:
//! [`AnalysisService::subscribe_stream`] registers a bounded
//! subscriber mailbox; an append submitted via
//! [`AnalysisService::append_stream_fanout`] computes the post-append
//! snapshot **once** and delivers it to every live subscriber as a
//! shared [`Arc`] ([`ServiceMetrics::fanout_delivered`] counts the
//! deliveries).  Mailboxes are bounded by [`ServiceConfig::result_cap`]
//! with evict-oldest semantics — a slow subscriber loses old snapshots
//! (visible via [`AnalysisService::subscription_lag`]) but never stalls
//! the producing stream.  Closing or quarantining a stream closes its
//! subscriptions ([`AnalysisService::poll_subscription`] then reports
//! [`SubRecv::Closed`] once drained).
//!
//! Results are delivered through **per-job completion slots**: a slot is
//! reserved at submit, filled by the worker, and consumed (freed) by
//! [`AnalysisService::wait`] / [`AnalysisService::poll`].  Unconsumed
//! results are *bounded* — at most [`ServiceConfig::result_cap`] finished
//! results are retained per shard (oldest evicted first), and
//! [`ServiceConfig::result_ttl`] expires them by age — so fire-and-forget
//! clients can no longer leak the result map (previously every unconsumed
//! [`JobResult`] lived forever).  Waiting on an id that was never
//! enqueued, was already consumed, or was evicted returns
//! [`WaitError::Unknown`] instead of blocking forever.
//!
//! [`ServiceMetrics`] are kept **per shard** plus one aggregate instance
//! (ticked alongside, both lock-free): `metrics()` is the fleet view,
//! `shard_metrics(k)` the per-shard one, and `aggregate == Σ shards`
//! always reconciles.
//!
//! ## Durability (per-shard WAL)
//!
//! With [`ServiceConfig::with_wal`] every stream mutation is logged to a
//! per-shard segment WAL ([`crate::coordinator::wal`]) **before** it is
//! applied: `Open` at [`AnalysisService::submit_stream`], one `Append`
//! record per packet (so replay re-applies with identical tile
//! boundaries — the restored profile is *bit-identical* to an
//! uninterrupted run), a full [`crate::mp::stampi::SessionState`]
//! `Snapshot` every [`crate::coordinator::wal::WalOptions::snapshot_every`]
//! appends, and `Close` at [`AnalysisService::close_stream`].  Restart
//! recovery ([`AnalysisService::try_start_sharded`]) replays each shard
//! directory, rebuilds every open stream (latest snapshot + appends
//! after it), re-checkpoints, and reclaims all pre-restart segments.
//! Closed streams are never resurrected.  In-memory job slots (pending
//! `wait` acks) do not survive a restart — clients re-read state via
//! [`AnalysisService::snapshot_stream`].
//!
//! Failure policy: a WAL write error disables the WAL on that shard for
//! the rest of the run (availability over durability), surfaced loudly
//! via [`ServiceMetrics::wal_errors`] and stderr.  A panicking job is
//! caught ([`std::panic::catch_unwind`]), failed, and counted in
//! [`ServiceMetrics::jobs_panicked`]; shard-level mutexes recover from
//! poisoning, so one bad job never takes the shard down.  A panic
//! *inside a stream apply* quarantines that stream (removed, `Close`d in
//! the WAL): its in-memory state can no longer be trusted, and replaying
//! the same packet would just re-panic.
//!
//! Design notes:
//! * channels + worker threads via the [`crate::sync`] facade (tokio is
//!   not in the offline vendor set; the queue semantics are identical
//!   for this shape) — which also means the whole protocol layer
//!   compiles against loom's model checker (`--cfg loom`,
//!   `rust/tests/loom_service.rs`),
//! * bounded queues => `submit` fails fast with
//!   [`SubmitError::Backpressure`] instead of buffering unboundedly,
//! * each job may carry its own window length and precision is fixed by
//!   the service's type parameter.
//!
//! ## Elastic sharding
//!
//! Three cooperating subsystems keep a skewed workload from turning one
//! shard into the slow memory channel everyone waits on (the NATSA
//! software analogue of placing work where the data is):
//!
//! * **hot-shard migration** ([`crate::coordinator::migrate`]) —
//!   quiesce a stream at its turn-seq barrier, hand its exact
//!   WAL-snapshot bytes to a peer shard, log `Close` here and
//!   `Open`+`Snapshot` there (durably, in that order reversed — target
//!   first), and flip the routing entry; profiles stay bit-identical
//!   across the hop and crash recovery composes via placement epochs;
//! * **autoscaling worker pools** ([`ElasticConfig`]) — per-shard pools
//!   grow/shrink between `min_workers..=max_workers` from queue-depth
//!   signals with hysteresis; workers exit only at job boundaries;
//! * **AIMD admission** ([`crate::coordinator::admission`], opt-in via
//!   [`ServiceConfig::with_admission`]) — a per-shard congestion window
//!   over in-flight work: overload fast-fails at submit
//!   ([`SubmitError::Backpressure`], counted in
//!   [`ServiceMetrics::admission_rejected`]) instead of piling up
//!   latency, and re-opens additively when the overload clears.
//!
//! Concurrency contract — lock hierarchy (`streams` map →
//! `entry.submit_seq` → `entry.state` → subscriber boxes, with the
//! router's `route_table` as a leaf above all; `try_lock` exempt), slot
//! lifecycle, poison policy — is documented in `docs/CONCURRENCY.md`
//! and enforced by the `tools/lint` scanner plus the loom models.

use std::collections::{HashMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::coordinator::admission::{AdmissionConfig, AimdController};
use crate::coordinator::fanout::{self, SubBox};
use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::migrate::{self, ElasticConfig, MigrateError};
use crate::coordinator::router::{Placement, Router};
use crate::coordinator::slots::{JobSlot, SlotStore, TakeError};
use crate::coordinator::wal::{self, StreamMeta, WalOptions, WalWriter};
use crate::mp::stampi::{Stampi, StampiConfig};
use crate::mp::MatrixProfile;
use crate::natsa::{NatsaConfig, NatsaEngine, StreamSession};
use crate::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use crate::sync::{lock_ok, thread, try_lock_ok, wait_ok, Arc, Condvar, Mutex, MutexGuard};
use crate::Real;

/// Shard index bits folded into every job/stream id (low bits), so id →
/// shard routing is a mask, not a table.
const SHARD_BITS: u32 = 8;

/// Hard shard-count ceiling implied by [`SHARD_BITS`].
pub const MAX_SHARDS: usize = 1 << SHARD_BITS;

/// The shard that owns a **job** or **subscription** id (valid for ids
/// handed out by [`AnalysisService::submit`] / `append_stream` /
/// `subscribe_stream`).  For **stream** ids this is only the mint-time
/// *hint* — hot-shard migration can re-home a stream, and the
/// epoch-versioned [`Router`] is the sole authority; stream callers go
/// through [`AnalysisService::stream_home`] / the internal resolve
/// path, never this mask.
pub fn shard_of(id: u64) -> usize {
    (id & (MAX_SHARDS as u64 - 1)) as usize
}

/// Stream-id hash for initial shard placement (splitmix64 finalizer:
/// cheap, well mixed, stable).
fn route_hash(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deployment shape of the service: how many shards, how big each one is,
/// how long unconsumed results may live, and whether streams are durable.
#[derive(Clone, Debug)]
pub struct ServiceConfig {
    /// Engine shards (clamped to 1..=[`MAX_SHARDS`]).  Streams hash to a
    /// shard; batch jobs go least-loaded-first.
    pub shards: usize,
    /// Worker threads per shard (>= 1).  A stream's pipelined appends can
    /// park at most this many workers in turn-waiting — and only on the
    /// stream's own shard.
    pub workers_per_shard: usize,
    /// Bounded queue depth per shard.
    pub queue_depth: usize,
    /// Most finished-but-unconsumed results retained per shard; beyond
    /// it, oldest results are evicted (their ids then report
    /// [`WaitError::Unknown`]).  Fire-and-forget clients should read
    /// state via [`AnalysisService::snapshot_stream`] instead.
    pub result_cap: usize,
    /// Optional age bound on unconsumed results.
    pub result_ttl: Option<Duration>,
    /// Durability root: when set, shard `k` logs every stream mutation
    /// to a segment WAL under `<dir>/shard-k/` and restart recovery
    /// replays it (see the module-level "Durability" section).
    pub wal_dir: Option<PathBuf>,
    /// WAL tuning (snapshot cadence, segment size, fsync policy); only
    /// meaningful together with [`Self::wal_dir`].
    pub wal_opts: WalOptions,
    /// Most jobs a worker drains from its shard queue per pass for
    /// cross-stream append coalescing (see the module-level
    /// "Cross-stream coalescing" section).  Default
    /// [`crate::mp::kernel::BAND`] — one full lane fill; values beyond
    /// it still group (the kernel chunks into `BAND`-wide sub-tiles).
    /// `<= 1` disables the drain pass entirely (every job runs the
    /// serial path).
    pub coalesce: usize,
    /// AIMD admission control (opt-in): when set, each shard carries a
    /// congestion window over in-flight jobs and overload fast-fails at
    /// submit with [`SubmitError::Backpressure`] instead of queueing
    /// unbounded latency.  `None` (default) admits everything the
    /// bounded queue accepts.
    pub admission: Option<AdmissionConfig>,
    /// Elastic sharding (opt-in): when set, a controller thread scales
    /// each shard's worker pool between the configured bounds and
    /// migrates hot streams to cold shards.  `None` (default) keeps the
    /// static `workers_per_shard` pools and mint-time placements.
    pub elastic: Option<ElasticConfig>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            shards: 1,
            workers_per_shard: 2,
            queue_depth: 16,
            result_cap: 1024,
            result_ttl: None,
            wal_dir: None,
            wal_opts: WalOptions::default(),
            coalesce: crate::mp::kernel::BAND,
            admission: None,
            elastic: None,
        }
    }
}

impl ServiceConfig {
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    pub fn with_workers(mut self, workers_per_shard: usize) -> Self {
        self.workers_per_shard = workers_per_shard;
        self
    }

    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    pub fn with_result_cap(mut self, cap: usize) -> Self {
        self.result_cap = cap;
        self
    }

    pub fn with_result_ttl(mut self, ttl: Duration) -> Self {
        self.result_ttl = Some(ttl);
        self
    }

    /// Persist streams to a per-shard WAL under `dir` and replay it on
    /// start (crash recovery is bit-identical — see the module docs).
    pub fn with_wal(mut self, dir: impl Into<PathBuf>) -> Self {
        self.wal_dir = Some(dir.into());
        self
    }

    /// Override the WAL's snapshot cadence / segment size / sync policy.
    pub fn with_wal_options(mut self, opts: WalOptions) -> Self {
        self.wal_opts = opts;
        self
    }

    /// Cap the per-pass drain width of cross-stream append coalescing
    /// (`<= 1` disables it).
    pub fn with_coalesce(mut self, coalesce: usize) -> Self {
        self.coalesce = coalesce;
        self
    }

    /// Gate admission per shard behind an AIMD congestion window.
    pub fn with_admission(mut self, cfg: AdmissionConfig) -> Self {
        self.admission = Some(cfg);
        self
    }

    /// Enable elastic sharding: autoscaling worker pools plus hot-shard
    /// stream migration, driven by a controller thread.
    pub fn with_elastic(mut self, cfg: ElasticConfig) -> Self {
        self.elastic = Some(cfg);
        self
    }

    fn normalized(mut self) -> Self {
        self.shards = self.shards.clamp(1, MAX_SHARDS);
        self.workers_per_shard = self.workers_per_shard.max(1);
        self.queue_depth = self.queue_depth.max(1);
        self.result_cap = self.result_cap.max(1);
        self.coalesce = self.coalesce.max(1);
        self.elastic = self.elastic.map(|e| e.normalized(self.workers_per_shard));
        self
    }
}

/// A submitted analysis job.
struct Job<T> {
    id: u64,
    payload: JobPayload<T>,
    submitted: Instant,
    /// The completion slot reserved at submit time; the worker fills it.
    slot: Arc<JobSlot<JobResult<T>>>,
}

/// What a job asks for.
enum JobPayload<T> {
    /// One-shot batch profile.
    Batch { series: Arc<Vec<T>>, m: usize },
    /// Append samples to an open stream (applied in `seq` order).
    /// `fanout` additionally delivers the post-append snapshot to every
    /// subscriber of the stream (computed once, delivered N times).
    StreamAppend { stream: u64, samples: Vec<T>, seq: u64, fanout: bool },
    /// Test-only panic injection: panics in the worker — immediately
    /// (`stream: None`), or after winning the stream's turn while
    /// holding its state lock (`Some`), the worst-case poisoning path.
    #[cfg(test)]
    Panic { stream: Option<u64>, seq: u64 },
}

/// Completed job result.  For stream appends, `profile` is the snapshot
/// right after the batch was applied (positions relative to the stream's
/// oldest retained window — see [`crate::mp::stampi::Stampi::profile`]).
#[derive(Clone, Debug)]
pub struct JobResult<T> {
    pub id: u64,
    pub profile: Result<MatrixProfile<T>, String>,
    pub queue_wait_s: f64,
    pub exec_s: f64,
}

/// Why a submission was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — caller should retry later (backpressure).  For batch
    /// jobs this means *every* shard's queue was full.
    Backpressure,
    /// Service is shutting down.
    Closed,
    /// The stream id is unknown or was closed.
    UnknownStream,
    /// The stream configuration was rejected (window/history bounds).
    Invalid(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
            SubmitError::UnknownStream => write!(f, "unknown or closed stream"),
            SubmitError::Invalid(why) => write!(f, "invalid stream config: {why}"),
        }
    }
}

/// Why [`AnalysisService::wait`] / `wait_timeout` did not return a result.
#[derive(Debug, PartialEq, Eq)]
pub enum WaitError {
    /// The id was never enqueued (e.g. the submit was rejected), its
    /// result was already consumed by an earlier `wait`/`poll`, or the
    /// unconsumed result aged out of the bounded retention
    /// ([`ServiceConfig::result_cap`] / [`ServiceConfig::result_ttl`]).
    /// The old service blocked forever on every one of these.
    Unknown,
    /// The deadline of [`AnalysisService::wait_timeout`] passed first;
    /// the job is still in flight and can be waited on again.
    Timeout,
}

impl std::fmt::Display for WaitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WaitError::Unknown => write!(f, "unknown job id (never enqueued, consumed, or evicted)"),
            WaitError::Timeout => write!(f, "timed out waiting for job"),
        }
    }
}

/// What [`AnalysisService::poll_subscription`] found in the mailbox
/// (the generic protocol lives in [`crate::coordinator::fanout`]; the
/// service instantiates it with the post-append profile snapshot).
pub type SubRecv<T> = fanout::SubRecv<MatrixProfile<T>>;

/// One open stream: the session plus the apply-order bookkeeping.
pub(crate) struct StreamState<T> {
    pub(crate) session: StreamSession<T>,
    /// Next sequence number to apply (appends wait their turn on `cv`).
    pub(crate) next_seq: u64,
    /// Set by `close_stream`: wakes and fails any waiting appends.
    pub(crate) closed: bool,
    /// Set by migration commit on the **source** entry: the stream is
    /// alive, just elsewhere.  Waiters and the group pass treat it like
    /// `closed` for this entry (give up, re-resolve), but clients see a
    /// retryable miss, not "stream closed".
    pub(crate) moved: bool,
    /// Placement epoch of this incarnation (logged in every WAL
    /// `Open`/`Snapshot` so restart recovery can pick the newest
    /// incarnation when a crash lands inside a migration window).
    pub(crate) epoch: u64,
    /// Appends applied since the last WAL snapshot (cadence counter;
    /// stays 0 while the shard's WAL is off or error-disabled).
    pub(crate) unsnapshotted: u32,
    /// Live subscriber mailboxes, delivered to under this state lock so
    /// per-subscriber snapshot order == apply order.  Closed boxes are
    /// dropped lazily at the next fanout delivery.
    pub(crate) subs: Vec<(u64, Arc<SubBox<MatrixProfile<T>>>)>,
}

pub(crate) struct StreamEntry<T> {
    pub(crate) state: Mutex<StreamState<T>>,
    pub(crate) cv: Condvar,
    /// Next sequence number to hand out.  Held across the (assign seq,
    /// enqueue) pair so queue order == seq order — the structural
    /// invariant the workers' turn-waiting relies on.
    pub(crate) submit_seq: Mutex<u64>,
    /// Set (before this entry leaves its shard's `streams` map) by
    /// close, quarantine and migration: a submitter that cloned the
    /// entry *before* the transition re-checks this after acquiring
    /// `submit_seq` and re-resolves instead of enqueueing a job no
    /// worker will ever match to a live map entry.
    pub(crate) gone: AtomicBool,
}

/// Per-shard autoscaling worker-pool bookkeeping (gauges for the
/// controller; workers themselves live in the service's join-handle
/// vec).  `size` counts live workers; `target` is where the controller
/// wants the pool — workers observing `size > target` exit at the next
/// job boundary via a CAS decrement.
#[derive(Debug)]
pub(crate) struct WorkerPool {
    pub(crate) size: AtomicU64,
    pub(crate) target: AtomicU64,
}

/// One engine shard: queue-fed workers, its own streams, slots, metrics,
/// and (when durability is on) its WAL writer.
pub(crate) struct Shard<T: Real> {
    pub(crate) slots: Mutex<SlotStore<JobResult<T>>>,
    pub(crate) streams: Mutex<HashMap<u64, Arc<StreamEntry<T>>>>,
    /// Subscription id → mailbox (the poll/unsubscribe index; the
    /// delivery index lives in each stream's `StreamState::subs`).
    /// Lock order: a stream's `state` lock may be held when taking
    /// this lock (subscribe does), never the reverse.
    pub(crate) subs: Mutex<HashMap<u64, Arc<SubBox<MatrixProfile<T>>>>>,
    pub(crate) metrics: ServiceMetrics,
    /// `None` = WAL off.  The inner `Option` goes `None` after the first
    /// write error (durability disabled for the shard, service alive).
    pub(crate) wal: Option<Mutex<Option<WalWriter<T>>>>,
    /// AIMD congestion window (admission control), when configured.
    pub(crate) admission: Option<AimdController>,
    pub(crate) pool: WorkerPool,
}

impl<T: Real> Shard<T> {
    /// Run `f` against this shard's WAL writer; no-op when the WAL is
    /// off or already failed.  The FIRST I/O error disables the shard's
    /// WAL — a half-written record would read as mid-log corruption once
    /// more records followed it, so continuing to log is worse than
    /// stopping — and is surfaced via `wal_errors` + stderr.
    ///
    /// Lock order: callers may hold a stream's `state` lock; never the
    /// reverse (a WAL holder never takes stream locks).
    pub(crate) fn with_wal(
        &self,
        aggregate: &ServiceMetrics,
        f: impl FnOnce(&mut WalWriter<T>) -> crate::Result<()>,
    ) {
        let Some(cell) = &self.wal else { return };
        let mut guard = lock_ok(cell);
        let Some(writer) = guard.as_mut() else { return };
        if let Err(e) = f(writer) {
            eprintln!("natsa wal: write failed ({e}); durability disabled on this shard until restart");
            self.metrics.wal_errors.fetch_add(1, Ordering::Relaxed);
            aggregate.wal_errors.fetch_add(1, Ordering::Relaxed);
            *guard = None;
        }
    }

    /// Whether this shard is still actually logging — false when the
    /// WAL was never configured *and* after an I/O error disabled it.
    /// Snapshot cadence checks this so a dead writer doesn't keep
    /// ticking the counter (or worse, keep paying for deep state
    /// copies that `with_wal` would just discard).
    pub(crate) fn wal_live(&self) -> bool {
        self.wal.as_ref().is_some_and(|cell| lock_ok(cell).is_some())
    }
}

/// Sharded multi-worker analysis service over the functional NATSA engine.
pub struct AnalysisService<T: Real> {
    /// Per-shard bounded queues (taken on shutdown).
    txs: Vec<Option<SyncSender<Job<T>>>>,
    shards: Vec<Arc<Shard<T>>>,
    /// Per-shard queue receivers, kept so the elastic controller can
    /// spawn additional workers onto a live shard.
    rxs: Vec<Arc<Mutex<Receiver<Job<T>>>>>,
    aggregate: Arc<ServiceMetrics>,
    /// Worker + controller join handles.  Shared with the controller
    /// thread, which pushes handles for the workers it spawns.
    workers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    /// Tells the elastic controller (and pool-shrinking workers) to stop.
    stop: Arc<AtomicBool>,
    /// Authoritative stream id → placement map (see module docs: the
    /// shard bits in a stream id are only the mint-time hint).
    router: Arc<Router>,
    next_job_seq: AtomicU64,
    next_stream_seq: AtomicU64,
    next_sub_seq: AtomicU64,
    /// Rotating tie-breaker for least-loaded batch routing.
    rr: AtomicU64,
    /// Shard k's slice of the engine configuration (remainder PUs are
    /// dealt to the first shards, so the slices sum to the whole fleet).
    shard_configs: Vec<NatsaConfig>,
    svc: ServiceConfig,
}

impl<T: Real> AnalysisService<T> {
    /// Start a single-shard service: `workers` worker threads over one
    /// bounded queue of `depth` (the pre-sharding shape; see
    /// [`Self::start_sharded`] for multi-shard deployments).
    pub fn start(config: NatsaConfig, workers: usize, depth: usize) -> Self {
        Self::start_sharded(
            config,
            ServiceConfig::default()
                .with_shards(1)
                .with_workers(workers.max(1))
                .with_queue_depth(depth),
        )
    }

    /// Start the sharded service.  `config` describes the *whole* PU
    /// fleet; shard `k` runs `config.shard_slice(svc.shards, k)`, so the
    /// shard fleets together still sum to the configured one.
    ///
    /// Panics when WAL recovery fails (corrupt directory, meta
    /// mismatch); use [`Self::try_start_sharded`] to handle that.
    pub fn start_sharded(config: NatsaConfig, svc: ServiceConfig) -> Self {
        Self::try_start_sharded(config, svc).expect("analysis service failed to start")
    }

    /// Fallible [`Self::start_sharded`]: errors instead of panicking
    /// when the configured WAL directory cannot be recovered (damaged
    /// segments, or a meta mismatch — the directory was written with a
    /// different dtype or shard count, under which the stream→shard
    /// routing would be wrong).
    pub fn try_start_sharded(config: NatsaConfig, svc: ServiceConfig) -> crate::Result<Self> {
        let svc = svc.normalized();
        let shard_configs: Vec<NatsaConfig> = (0..svc.shards)
            .map(|k| config.shard_slice(svc.shards, k))
            .collect();
        if let Some(dir) = &svc.wal_dir {
            check_wal_meta::<T>(dir, svc.shards)?;
        }
        let aggregate = Arc::new(ServiceMetrics::default());
        let mut txs = Vec::with_capacity(svc.shards);
        let mut shards = Vec::with_capacity(svc.shards);
        let mut rxs = Vec::with_capacity(svc.shards);
        let mut workers = Vec::with_capacity(svc.shards * svc.workers_per_shard);
        // Phase 1 — replay every shard directory.  Two high-water marks
        // cross shards: the highest stream sequence ever issued (the id
        // counter must restart strictly past every id the directory has
        // ever seen — `Replay::max_stream` is fed by the segment
        // headers' high-water field, so even ids whose records were
        // compacted away stay retired), and the highest placement epoch
        // any *live* stream carries (the router's allocator must restart
        // strictly past it, or a post-restart migration could mint an
        // epoch that loses a recovery dedupe it should win).
        let mut max_stream_seq = 0u64;
        let mut max_epoch = 0u64;
        let mut replays: Vec<Option<wal::Replay<T>>> = Vec::with_capacity(svc.shards);
        for k in 0..svc.shards {
            if let Some(dir) = &svc.wal_dir {
                let shard_dir = dir.join(format!("shard-{k}"));
                let replay = wal::replay::<T>(&shard_dir)?;
                max_stream_seq = max_stream_seq.max(replay.max_stream >> SHARD_BITS);
                max_epoch = max_epoch.max(replay.max_epoch);
                replays.push(Some(replay));
            } else {
                replays.push(None);
            }
        }
        // Phase 2 — resolve each stream's home.  A crash inside a
        // migration's commit window leaves the stream Open in TWO shard
        // directories (the target's Open+Snapshot are synced before the
        // source's Close is written); the incarnation with the higher
        // placement epoch is the newer one and wins.  Epoch ties cannot
        // cross shards (epochs are globally unique; legacy epoch-0 logs
        // predate migration, under which a stream lived on exactly one
        // shard for life).
        let mut homes: HashMap<u64, (usize, u64)> = HashMap::new();
        for (k, rp) in replays.iter().enumerate() {
            let Some(rp) = rp else { continue };
            for rs in &rp.streams {
                match homes.get(&rs.id) {
                    Some(&(_, epoch)) if epoch >= rs.epoch => {}
                    _ => {
                        homes.insert(rs.id, (k, rs.epoch));
                    }
                }
            }
        }
        let router = Arc::new(Router::new(max_epoch));
        // Phase 3 — per shard: resume the writer, close loser
        // incarnations, restore winners, route them.
        for (k, &shard_config) in shard_configs.iter().enumerate() {
            let mut streams: HashMap<u64, Arc<StreamEntry<T>>> = HashMap::new();
            let mut wal_writer = None;
            if let Some(replay) = replays[k].take() {
                let dir = svc.wal_dir.as_ref().expect("replay implies wal_dir");
                let shard_dir = dir.join(format!("shard-{k}"));
                let mut writer = WalWriter::resume(&shard_dir, svc.wal_opts.clone(), &replay)?;
                let mut checkpoints = Vec::new();
                let mut dropped = Vec::new();
                for rs in replay.streams {
                    if homes.get(&rs.id) != Some(&(k, rs.epoch)) {
                        // Stale incarnation from an interrupted
                        // migration: the stream's newer home is another
                        // shard.  Finish the migration's intent by
                        // closing it here.
                        dropped.push(rs.id);
                        continue;
                    }
                    match restore_stream(&rs, shard_config.pus.max(1)) {
                        Ok((session, next_seq)) => {
                            checkpoints.push((rs.id, rs.epoch, next_seq, session.state()));
                            router.install(rs.id, Placement { shard: k, epoch: rs.epoch });
                            streams.insert(
                                rs.id,
                                Arc::new(StreamEntry {
                                    state: Mutex::new(StreamState {
                                        session,
                                        next_seq,
                                        closed: false,
                                        moved: false,
                                        epoch: rs.epoch,
                                        unsnapshotted: 0,
                                        subs: Vec::new(),
                                    }),
                                    cv: Condvar::new(),
                                    submit_seq: Mutex::new(next_seq),
                                    gone: AtomicBool::new(false),
                                }),
                            );
                        }
                        Err(why) => {
                            eprintln!(
                                "natsa wal: shard {k}: dropping unrecoverable stream {}: {why}",
                                rs.id
                            );
                            dropped.push(rs.id);
                        }
                    }
                }
                // A dropped stream is a closed stream: logging the Close
                // releases its (resume-seeded) pin so it cannot stall
                // compaction forever, and keeps later replays from
                // resurrecting a session we already failed to restore
                // (or a stale pre-migration incarnation).
                for id in dropped {
                    writer.log_close(id)?;
                }
                // Fresh snapshot of everything we restored, then reclaim
                // every pre-restart segment (snapshots are synced before
                // anything is deleted; the seeded pins keep mid-checkpoint
                // rotations from reclaiming early).
                writer.checkpoint(&checkpoints)?;
                wal_writer = Some(Mutex::new(Some(writer)));
            }
            let (tx, rx) = sync_channel::<Job<T>>(svc.queue_depth);
            let rx = Arc::new(Mutex::new(rx));
            let shard = Arc::new(Shard {
                slots: Mutex::new(SlotStore::new()),
                streams: Mutex::new(streams),
                subs: Mutex::new(HashMap::new()),
                metrics: ServiceMetrics::default(),
                wal: wal_writer,
                admission: svc.admission.clone().map(AimdController::new),
                pool: WorkerPool {
                    size: AtomicU64::new(svc.workers_per_shard as u64),
                    target: AtomicU64::new(svc.workers_per_shard as u64),
                },
            });
            ServiceMetrics::publish_gauge(
                &shard.metrics.pool_workers,
                &aggregate.pool_workers,
                svc.workers_per_shard as u64,
            );
            if let Some(adm) = &shard.admission {
                ServiceMetrics::publish_gauge(
                    &shard.metrics.cwnd_milli,
                    &aggregate.cwnd_milli,
                    adm.cwnd_milli(),
                );
            }
            for _ in 0..svc.workers_per_shard {
                workers.push(spawn_worker(
                    rx.clone(),
                    shard.clone(),
                    aggregate.clone(),
                    router.clone(),
                    shard_config,
                    svc.clone(),
                ));
            }
            txs.push(Some(tx));
            rxs.push(rx);
            shards.push(shard);
        }
        let workers = Arc::new(Mutex::new(workers));
        let stop = Arc::new(AtomicBool::new(false));
        if let Some(ecfg) = svc.elastic.clone() {
            let ctx = migrate::ControllerCtx {
                shards: shards.clone(),
                rxs: rxs.clone(),
                router: router.clone(),
                aggregate: aggregate.clone(),
                shard_configs: shard_configs.clone(),
                svc: svc.clone(),
                workers: workers.clone(),
                stop: stop.clone(),
            };
            lock_ok(&workers).push(thread::spawn(move || migrate::controller_loop(ctx, ecfg)));
        }
        Ok(AnalysisService {
            txs,
            shards,
            rxs,
            aggregate,
            workers,
            stop,
            router,
            next_job_seq: AtomicU64::new(1),
            next_stream_seq: AtomicU64::new(max_stream_seq + 1),
            next_sub_seq: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            shard_configs,
            svc,
        })
    }

    /// Submit a batch job to the least-loaded shard, spilling to the next
    /// shard when a queue is full; fails fast with
    /// [`SubmitError::Backpressure`] only when *every* shard is full.
    pub fn submit(&self, series: Arc<Vec<T>>, m: usize) -> Result<u64, SubmitError> {
        let n = self.shards.len();
        let start = (self.rr.fetch_add(1, Ordering::Relaxed) % n as u64) as usize;
        let mut order: Vec<usize> = (0..n).map(|k| (start + k) % n).collect();
        // cached keys: each shard's load is snapshotted once, so the
        // comparator stays a total order even while workers tick the
        // atomics; stable sort keeps the rotated order among equal loads
        order.sort_by_cached_key(|&k| self.shards[k].metrics.in_flight());
        for &k in &order {
            match self.try_enqueue(k, JobPayload::Batch { series: series.clone(), m }) {
                Ok(id) => return Ok(id),
                Err(SubmitError::Backpressure) => continue, // spill to next shard
                Err(e) => return Err(e),
            }
        }
        self.shards[order[0]]
            .metrics
            .jobs_rejected
            .fetch_add(1, Ordering::Relaxed);
        self.aggregate.jobs_rejected.fetch_add(1, Ordering::Relaxed);
        Err(SubmitError::Backpressure)
    }

    /// Open a streaming session with window `m` (and an optional retained
    /// history bound in samples).  Returns the stream id to append to.
    /// The stream is *placed* on a shard by hashing the id — and from
    /// then on routed through the epoch-versioned table, which hot-shard
    /// migration may repoint (see [`Self::migrate_stream`]).
    pub fn submit_stream(&self, m: usize, max_history: Option<usize>) -> Result<u64, SubmitError> {
        let seq = self.next_stream_seq.fetch_add(1, Ordering::Relaxed);
        let shard_idx = (route_hash(seq) % self.shards.len() as u64) as usize;
        self.open_stream_at(shard_idx, seq, m, max_history)
    }

    /// [`Self::submit_stream`] with an explicit initial shard (tests and
    /// benchmarks pinning placement; `shard_idx` must be in range).
    pub fn submit_stream_on(
        &self,
        shard_idx: usize,
        m: usize,
        max_history: Option<usize>,
    ) -> Result<u64, SubmitError> {
        if shard_idx >= self.shards.len() {
            return Err(SubmitError::Invalid(format!(
                "shard {shard_idx} out of range ({} shards)",
                self.shards.len()
            )));
        }
        let seq = self.next_stream_seq.fetch_add(1, Ordering::Relaxed);
        self.open_stream_at(shard_idx, seq, m, max_history)
    }

    fn open_stream_at(
        &self,
        shard_idx: usize,
        seq: u64,
        m: usize,
        max_history: Option<usize>,
    ) -> Result<u64, SubmitError> {
        let session = NatsaEngine::<T>::new(self.shard_configs[shard_idx])
            .open_stream_bounded(m, max_history)
            .map_err(|e| SubmitError::Invalid(e.to_string()))?;
        let id = (seq << SHARD_BITS) | shard_idx as u64;
        // The packed shard bits are only the mint-time hint; they must
        // agree with the actual initial placement exactly here, at mint.
        debug_assert_eq!(shard_of(id), shard_idx, "mint-time hint must match placement");
        let epoch = self.router.next_epoch();
        let entry = Arc::new(StreamEntry {
            state: Mutex::new(StreamState {
                session,
                next_seq: 0,
                closed: false,
                moved: false,
                epoch,
                unsnapshotted: 0,
                subs: Vec::new(),
            }),
            cv: Condvar::new(),
            submit_seq: Mutex::new(0),
            gone: AtomicBool::new(false),
        });
        let shard = &self.shards[shard_idx];
        // Write-ahead: log the Open BEFORE the stream becomes visible,
        // so no Append can ever precede its stream's Open in the log.
        // (A crash in between leaves an empty stream in the WAL whose id
        // no client holds — replayed as an idle session, harmless.)
        shard.with_wal(&self.aggregate, |w| {
            w.log_open(
                id,
                StreamMeta {
                    m,
                    excl: self.shard_configs[shard_idx].excl,
                    max_history,
                    epoch,
                },
            )
        });
        // Visibility order: shard map first, router last — a client that
        // resolves the placement must find the map entry (resolve relies
        // on it; see `resolve_stream`).
        lock_ok(&shard.streams).insert(id, entry);
        self.router.install(id, Placement { shard: shard_idx, epoch });
        Ok(id)
    }

    /// The shard currently hosting `stream` (`None` when unknown or
    /// closed).  Snapshot only — migration may re-home the stream right
    /// after this returns; callers wanting the entry go through the
    /// internal resolve path, which retries the race.
    pub fn stream_home(&self, stream: u64) -> Option<usize> {
        self.router.lookup(stream).map(|p| p.shard)
    }

    /// Resolve `stream` to its current home: placement plus the live map
    /// entry on that shard.  Retries the transient windows in which the
    /// router and the shard maps disagree (mint: map insert → router
    /// install; migration commit: target map insert → flip → source map
    /// remove; close: router remove → map remove) — each window is
    /// bounded by the writer finishing its sequence, and every retry
    /// re-reads the router, so this terminates.
    fn resolve_stream(&self, stream: u64) -> Result<(Placement, Arc<StreamEntry<T>>), SubmitError> {
        loop {
            let Some(p) = self.router.lookup(stream) else {
                return Err(SubmitError::UnknownStream);
            };
            let shard = self.shards.get(p.shard).ok_or(SubmitError::UnknownStream)?;
            if let Some(entry) = lock_ok(&shard.streams).get(&stream).cloned() {
                return Ok((p, entry));
            }
            // Router said `p.shard` but the map has no entry: either the
            // stream just closed (next lookup misses), just migrated
            // (next lookup names the new home), or — mint/commit
            // mid-flight — the entry is about to appear.  Re-read;
            // yield only when the placement is unchanged.
            match self.router.lookup(stream) {
                None => return Err(SubmitError::UnknownStream),
                Some(p2) if p2 != p => continue,
                Some(_) => thread::yield_now(),
            }
        }
    }

    /// Enqueue a batch of samples against stream `stream`, onto the
    /// stream's own shard.  Returns a job id to [`Self::wait`] on; its
    /// result's profile is the post-append snapshot.  Appends from one
    /// client that are submitted in order are applied in order
    /// (per-stream sequencing).
    ///
    /// A client that *pipelines* many appends to one stream can park up
    /// to `workers_per_shard` workers in turn-waiting — on this stream's
    /// shard only; other shards (and batch jobs, which route around load)
    /// are unaffected.  Unconsumed append results are bounded by
    /// [`ServiceConfig::result_cap`]/[`ServiceConfig::result_ttl`], so
    /// fire-and-forget feeding plus [`Self::snapshot_stream`] reads no
    /// longer leak.
    pub fn append_stream(&self, stream: u64, samples: &[T]) -> Result<u64, SubmitError> {
        self.append_stream_inner(stream, samples, false)
    }

    /// Like [`Self::append_stream`], additionally delivering the
    /// post-append snapshot to every live subscriber of the stream
    /// (registered via [`Self::subscribe_stream`]): the append — and
    /// its snapshot — is computed **once**, then handed to N mailboxes
    /// as a shared `Arc`.  Single-sample fanout appends coalesce onto
    /// shared row tiles like plain appends.
    pub fn append_stream_fanout(&self, stream: u64, samples: &[T]) -> Result<u64, SubmitError> {
        self.append_stream_inner(stream, samples, true)
    }

    fn append_stream_inner(
        &self,
        stream: u64,
        samples: &[T],
        fanout: bool,
    ) -> Result<u64, SubmitError> {
        loop {
            let (p, entry) = self.resolve_stream(stream)?;
            let shard = &self.shards[p.shard];
            // Hold the stream's seq lock across (assign seq, enqueue) so
            // queue order equals sequence order — the workers rely on it.
            let mut seq_guard = lock_ok(&entry.submit_seq);
            if entry.gone.load(Ordering::Acquire) {
                // The entry left its shard (close / quarantine /
                // migration committed) between our resolve and taking
                // its seq lock; a job enqueued against it would never
                // find a live stream.  Re-resolve: a migrated stream
                // admits the append at its new home, a closed one
                // reports UnknownStream.
                drop(seq_guard);
                continue;
            }
            let seq = *seq_guard;
            let result = self.try_enqueue(
                p.shard,
                JobPayload::StreamAppend { stream, samples: samples.to_vec(), seq, fanout },
            );
            match result {
                Ok(_) => *seq_guard += 1,
                Err(SubmitError::Backpressure) => {
                    shard.metrics.jobs_rejected.fetch_add(1, Ordering::Relaxed);
                    self.aggregate.jobs_rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(_) => {}
            }
            return result;
        }
    }

    /// Register a snapshot subscriber on `stream`; returns the
    /// subscription id for [`Self::poll_subscription`] /
    /// [`Self::unsubscribe`].  Every subsequent
    /// [`Self::append_stream_fanout`] on the stream delivers its
    /// post-append snapshot into this subscription's bounded mailbox
    /// (at most [`ServiceConfig::result_cap`] retained; oldest evicted
    /// first — see [`Self::subscription_lag`]).
    pub fn subscribe_stream(&self, stream: u64) -> Result<u64, SubmitError> {
        loop {
            let (p, entry) = self.resolve_stream(stream)?;
            let shard = &self.shards[p.shard];
            // The subscription id's packed bits name the shard whose
            // `subs` index holds the mailbox — that binding is real
            // authority (unsubscribe/poll mask it), so a migration
            // racing us must be retried, not ignored.
            let seq = self.next_sub_seq.fetch_add(1, Ordering::Relaxed);
            let id = (seq << SHARD_BITS) | p.shard as u64;
            let sb = SubBox::new();
            // Registration is atomic under the stream's state lock (the
            // documented state → subs-map order): a close racing in
            // behind us finds the box in `subs` and closes it properly.
            let mut st = lock_ok(&entry.state);
            if st.closed {
                return Err(SubmitError::UnknownStream);
            }
            if st.moved || entry.gone.load(Ordering::Acquire) {
                // Migration committed between resolve and this lock: the
                // live subscriber list moved to the new home's entry.
                drop(st);
                continue;
            }
            st.subs.push((id, sb.clone()));
            lock_ok(&shard.subs).insert(id, sb);
            return Ok(id);
        }
    }

    /// Tear down a subscription.  Fanout deliveries skip it from now on
    /// (and drop it from the stream's delivery list at the next fanout);
    /// queued-but-unpolled snapshots are discarded.  Returns whether the
    /// id was live.
    pub fn unsubscribe(&self, sub: u64) -> bool {
        let Some(shard) = self.shards.get(shard_of(sub)) else {
            return false;
        };
        match lock_ok(&shard.subs).remove(&sub) {
            Some(sb) => {
                sb.close();
                true
            }
            None => false,
        }
    }

    /// Take the oldest undelivered snapshot from a subscription's
    /// mailbox (never blocks — see [`SubRecv`]).  After the stream is
    /// closed or quarantined, queued snapshots remain pollable until
    /// drained, then [`SubRecv::Closed`].
    pub fn poll_subscription(&self, sub: u64) -> SubRecv<T> {
        let Some(shard) = self.shards.get(shard_of(sub)) else {
            return SubRecv::Closed;
        };
        let Some(sb) = lock_ok(&shard.subs).get(&sub).cloned() else {
            return SubRecv::Closed;
        };
        sb.poll()
    }

    /// Snapshots this subscription has lost to the bounded mailbox
    /// (evict-oldest backpressure).  `None` for unknown/torn-down ids.
    pub fn subscription_lag(&self, sub: u64) -> Option<u64> {
        let shard = self.shards.get(shard_of(sub))?;
        let sb = lock_ok(&shard.subs).get(&sub).cloned()?;
        Some(sb.dropped())
    }

    /// The standard pipelined feeding loop over [`Self::append_stream`]:
    /// try to append; while the stream's shard is backpressured, consume
    /// (block on) the *oldest* in-flight ack from `pending` and retry.
    /// On success the accepted job id is pushed onto `pending` and
    /// returned together with every result consumed along the way, for
    /// the caller to inspect (acks that were already consumed or evicted
    /// are skipped).  This is the one place the client-side backpressure
    /// contract lives — the CLI `serve` demo, the shard-scaling bench,
    /// and the stress tests all feed through it.
    pub fn append_stream_pipelined(
        &self,
        stream: u64,
        samples: &[T],
        pending: &mut VecDeque<u64>,
    ) -> Result<(u64, Vec<JobResult<T>>), SubmitError> {
        let mut drained = Vec::new();
        loop {
            match self.append_stream(stream, samples) {
                Ok(id) => {
                    pending.push_back(id);
                    return Ok((id, drained));
                }
                Err(SubmitError::Backpressure) => match pending.pop_front() {
                    Some(oldest) => {
                        if let Ok(r) = self.wait(oldest) {
                            drained.push(r);
                        }
                    }
                    // queue full with nothing of ours in flight: other
                    // clients own the queue — back off briefly
                    None => std::thread::sleep(Duration::from_micros(200)),
                },
                Err(e) => return Err(e),
            }
        }
    }

    /// Test hook: enqueue a job whose execution panics.  Batch-shaped
    /// (no stream) on shard 0 — exercises catch-unwind without
    /// quarantine side effects.
    #[cfg(test)]
    fn submit_panic(&self) -> Result<u64, SubmitError> {
        self.try_enqueue(0, JobPayload::Panic { stream: None, seq: 0 })
    }

    /// Test hook: enqueue a panicking job *sequenced onto a stream* like
    /// a real append (takes a turn, panics holding the state lock) —
    /// exercises the quarantine path.
    #[cfg(test)]
    fn append_stream_panic(&self, stream: u64) -> Result<u64, SubmitError> {
        loop {
            let (p, entry) = self.resolve_stream(stream)?;
            let mut seq_guard = lock_ok(&entry.submit_seq);
            if entry.gone.load(Ordering::Acquire) {
                drop(seq_guard);
                continue;
            }
            let seq = *seq_guard;
            let result = self.try_enqueue(p.shard, JobPayload::Panic { stream: Some(stream), seq });
            if result.is_ok() {
                *seq_guard += 1;
            }
            return result;
        }
    }

    /// Reserve a completion slot and enqueue onto shard `shard_idx`.
    /// `jobs_submitted` is ticked for accepted jobs (pre-send, rolled
    /// back on rejection); the *caller* accounts rejections (batch
    /// submits spill across shards first).
    fn try_enqueue(&self, shard_idx: usize, payload: JobPayload<T>) -> Result<u64, SubmitError> {
        let shard = &self.shards[shard_idx];
        let tx = self.txs[shard_idx].as_ref().ok_or(SubmitError::Closed)?;
        // AIMD admission gate (opt-in): refuse before reserving anything
        // when the shard's in-flight load fills its congestion window.
        if let Some(adm) = &shard.admission {
            if !adm.try_acquire(shard.metrics.in_flight()) {
                shard.metrics.admission_rejected.fetch_add(1, Ordering::Relaxed);
                self.aggregate.admission_rejected.fetch_add(1, Ordering::Relaxed);
                return Err(SubmitError::Backpressure);
            }
        }
        let seq = self.next_job_seq.fetch_add(1, Ordering::Relaxed);
        let id = (seq << SHARD_BITS) | shard_idx as u64;
        let slot = {
            let mut store = lock_ok(&shard.slots);
            let slot = store.reserve(id);
            store.evict(self.svc.result_cap, self.svc.result_ttl);
            slot
        };
        let job = Job { id, payload, submitted: Instant::now(), slot };
        // Tick submitted BEFORE the send (rolled back on rejection): a
        // worker that finishes the job microseconds after try_send must
        // never observe completed > submitted, or in_flight() would
        // saturate to 0 mid-run and mislead the least-loaded router and
        // any drained-yet probe.  The rollback window only ever
        // over-counts, which is the conservative direction for both.
        shard.metrics.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.aggregate.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        match tx.try_send(job) {
            Ok(()) => Ok(id),
            Err(e) => {
                shard.metrics.jobs_submitted.fetch_sub(1, Ordering::Relaxed);
                self.aggregate.jobs_submitted.fetch_sub(1, Ordering::Relaxed);
                lock_ok(&shard.slots).forget(id);
                match e {
                    TrySendError::Full(_) => {
                        // Hard congestion: the bounded queue itself
                        // refused — shrink the window multiplicatively.
                        if let Some(adm) = &shard.admission {
                            adm.on_congestion();
                            ServiceMetrics::publish_gauge(
                                &shard.metrics.cwnd_milli,
                                &self.aggregate.cwnd_milli,
                                adm.cwnd_milli(),
                            );
                        }
                        Err(SubmitError::Backpressure)
                    }
                    TrySendError::Disconnected(_) => Err(SubmitError::Closed),
                }
            }
        }
    }

    /// Read a stream's live profile without going through the queue.
    /// `None` if the stream is unknown or closed.
    pub fn snapshot_stream(&self, stream: u64) -> Option<MatrixProfile<T>> {
        loop {
            let (_, entry) = self.resolve_stream(stream).ok()?;
            let state = lock_ok(&entry.state);
            if state.moved {
                // Migration won the race to this entry; the session (and
                // any appends since) lives at the new home — re-resolve.
                drop(state);
                continue;
            }
            return Some(state.session.profile());
        }
    }

    /// Close a stream.  Semantics are **reject, not drain**: the append
    /// currently *applying* (holding the stream's state lock) finishes
    /// first and its record precedes the `Close` in the WAL; every
    /// queued-but-not-yet-applied append — pipelined in-flight ones
    /// included — fails with a "stream closed" result and is never
    /// logged.  Callers that want drain-then-close wait their pending
    /// acks first (the [`Self::append_stream_pipelined`] contract).
    /// After a restart the stream stays closed: replay never resurrects
    /// a `Close`d stream.  Returns whether the id was open.
    pub fn close_stream(&self, stream: u64) -> bool {
        loop {
            let Ok((p, e)) = self.resolve_stream(stream) else {
                return false;
            };
            let shard = &self.shards[p.shard];
            // Mark closed and log the Close under the state lock: an
            // append holds that lock from turn-win through WAL log and
            // apply, so nothing of this stream's can enter the log
            // after its Close record.
            let mut st = lock_ok(&e.state);
            if st.closed {
                return false;
            }
            if st.moved {
                // A migration committed this entry away first; close the
                // stream at its new home.
                drop(st);
                continue;
            }
            // Commit the close against the exact placement we resolved
            // (CAS): losing means a migration flipped the entry
            // concurrently — but `moved` is set under the state lock we
            // hold, so a loss here can only be a stale pre-lock read.
            if !self.router.remove_if(stream, p) {
                drop(st);
                continue;
            }
            st.closed = true;
            e.gone.store(true, Ordering::Release);
            shard.with_wal(&self.aggregate, |w| w.log_close(stream));
            fanout::close_all(&mut st.subs);
            // Lock order: `streams` (class below `state`) must not be
            // acquired while `state` is held — drop first.  The entry
            // stays resolvable in the gap; `closed` + the router removal
            // already make every path report the stream gone.
            drop(st);
            lock_ok(&shard.streams).remove(&stream);
            e.cv.notify_all();
            return true;
        }
    }

    /// Block until job `id` completes and take its result.  Errors with
    /// [`WaitError::Unknown`] — immediately, never blocking — when the id
    /// was never enqueued (e.g. its submit was rejected with
    /// backpressure), was already consumed, or was evicted from the
    /// bounded result retention.
    pub fn wait(&self, id: u64) -> Result<JobResult<T>, WaitError> {
        self.wait_deadline(id, None)
    }

    /// Like [`Self::wait`], giving up with [`WaitError::Timeout`] after
    /// `timeout` (the job stays in flight and can be waited on again).
    ///
    /// An overflowing deadline (`Instant::now() + Duration::MAX` has no
    /// representation) degrades to an untimed wait instead of panicking.
    pub fn wait_timeout(&self, id: u64, timeout: Duration) -> Result<JobResult<T>, WaitError> {
        self.wait_deadline(id, Instant::now().checked_add(timeout))
    }

    fn wait_deadline(&self, id: u64, deadline: Option<Instant>) -> Result<JobResult<T>, WaitError> {
        let shard = self.shards.get(shard_of(id)).ok_or(WaitError::Unknown)?;
        // The store lock is dropped before blocking on the slot (the
        // store and a slot's own lock are never held together — see
        // [`crate::coordinator::slots`] for the wait loop and its
        // timeout/consume-exactly-once semantics).
        let slot = lock_ok(&shard.slots).get(id).ok_or(WaitError::Unknown)?;
        match slot.take(deadline) {
            Ok(result) => {
                lock_ok(&shard.slots).consumed(id);
                Ok(result)
            }
            // a racing wait on the same id consumed it first
            Err(TakeError::Consumed) => Err(WaitError::Unknown),
            Err(TakeError::Timeout) => Err(WaitError::Timeout),
        }
    }

    /// Non-blocking poll; takes (and frees) the result when finished.
    /// `None` while the job is in flight — and also for unknown/consumed/
    /// evicted ids (use [`Self::wait`] to distinguish).
    pub fn poll(&self, id: u64) -> Option<JobResult<T>> {
        let shard = self.shards.get(shard_of(id))?;
        let slot = lock_ok(&shard.slots).get(id)?;
        let result = slot.try_take()?;
        lock_ok(&shard.slots).consumed(id);
        Some(result)
    }

    /// Fleet-wide (aggregate) metrics — always `Σ` of the per-shard ones.
    pub fn metrics(&self) -> &ServiceMetrics {
        &self.aggregate
    }

    /// Number of engine shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Metrics of shard `k` (panics when `k >= num_shards()`).
    pub fn shard_metrics(&self, k: usize) -> &ServiceMetrics {
        &self.shards[k].metrics
    }

    /// Completion slots currently held across all shards (in-flight jobs
    /// plus finished-but-unconsumed results).  After a full drain with
    /// every result consumed this is 0 — no [`JobResult`] survives its
    /// consumer.
    pub fn retained_results(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_ok(&s.slots).len())
            .sum()
    }

    /// Migrate `stream` to shard `to`: quiesce its appends at the
    /// turn-seq barrier, install its exact WAL-snapshot state on the
    /// target (durably, before the source logs its `Close`), and flip
    /// the routing entry.  Appends admitted before the flip apply on the
    /// source; appends admitted after resolve to the target — profiles
    /// are bit-identical across the hop.  The elastic controller calls
    /// this automatically when configured; it is public for explicit
    /// rebalancing (and the tests).
    pub fn migrate_stream(&self, stream: u64, to: usize) -> Result<(), MigrateError> {
        migrate::run_migration(
            &migrate::MigrateCtx {
                shards: &self.shards,
                router: &self.router,
                aggregate: &self.aggregate,
                shard_configs: &self.shard_configs,
            },
            stream,
            to,
        )
    }

    /// Stop accepting jobs, drain every shard's queue, join workers.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::Release);
        let mut txs = self.txs;
        for tx in &mut txs {
            tx.take(); // close the shard's channel
        }
        let handles: Vec<thread::JoinHandle<()>> = {
            let mut w = lock_ok(&self.workers);
            w.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
        // Workers are gone, so the log is quiescent — one final fsync
        // per shard makes everything acked before shutdown durable.
        for shard in self.shards.iter() {
            shard.with_wal(&self.aggregate, |w| w.sync());
        }
    }
}

/// The WAL directory's identity card: replaying under a different dtype
/// would decode garbage, and a different shard count would route every
/// stream to the wrong shard directory — both are pinned at first use.
fn check_wal_meta<T: Real>(dir: &Path, shards: usize) -> crate::Result<()> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join("wal.meta");
    let want = format!("natsa-wal v1 dtype={} shards={shards}\n", T::DTYPE);
    match std::fs::read_to_string(&path) {
        Ok(got) => anyhow::ensure!(
            got == want,
            "wal dir {} was written as '{}' but is being opened as '{}'",
            dir.display(),
            got.trim(),
            want.trim()
        ),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            // The identity card must actually survive a crash: sync the
            // file contents AND its directory entry, or a restart could
            // find synced segments guarded by no meta at all.
            use std::io::Write as _;
            let mut f = std::fs::File::create(&path)?;
            f.write_all(want.as_bytes())?;
            f.sync_all()?;
            #[cfg(unix)]
            std::fs::File::open(dir)?.sync_all()?;
        }
        Err(e) => return Err(e.into()),
    }
    Ok(())
}

/// Rebuild one stream from its replayed WAL records: the latest snapshot
/// (or a fresh session from the `Open` metadata), then the appends after
/// it — re-applied packet-by-packet, so tile boundaries (and therefore
/// every bit of the profile) match the uninterrupted run.
///
/// Restoration runs the same engine code as live appends, so a
/// deterministic engine panic would re-fire here — catch it and drop the
/// one stream instead of killing the whole service start.
fn restore_stream<T: Real>(
    rs: &wal::ReplayedStream<T>,
    pus: usize,
) -> Result<(StreamSession<T>, u64), String> {
    catch_unwind(AssertUnwindSafe(|| -> crate::Result<(StreamSession<T>, u64)> {
        let mut session = match &rs.snapshot {
            Some((_, state)) => StreamSession::from_state(state.clone(), pus)?,
            None => {
                let mut cfg = StampiConfig::new(rs.meta.m);
                if let Some(e) = rs.meta.excl {
                    cfg = cfg.with_excl(e);
                }
                if let Some(h) = rs.meta.max_history {
                    cfg = cfg.with_max_history(h);
                }
                StreamSession::from_state(Stampi::new(cfg)?.state(), pus)?
            }
        };
        for (_, packet) in &rs.appends {
            // Replay consumes records already in the log; re-logging them
            // here would double every append on the next recovery.
            // natsa-lint: allow(wal_order)
            session.extend(packet);
        }
        Ok((session, rs.next_seq()))
    }))
    .map_err(|_| "replay panicked".to_string())?
    .map_err(|e| e.to_string())
}

/// Spawn one worker thread onto a shard's shared queue receiver (used
/// at startup and by the elastic controller growing a pool).
pub(crate) fn spawn_worker<T: Real>(
    rx: Arc<Mutex<Receiver<Job<T>>>>,
    shard: Arc<Shard<T>>,
    aggregate: Arc<ServiceMetrics>,
    router: Arc<Router>,
    config: NatsaConfig,
    svc: ServiceConfig,
) -> thread::JoinHandle<()> {
    thread::spawn(move || worker_loop(rx, shard, aggregate, router, config, svc))
}

fn worker_loop<T: Real>(
    rx: Arc<Mutex<Receiver<Job<T>>>>,
    shard: Arc<Shard<T>>,
    aggregate: Arc<ServiceMetrics>,
    router: Arc<Router>,
    config: NatsaConfig,
    svc: ServiceConfig,
) {
    let engine = NatsaEngine::<T>::new(config);
    loop {
        // Pool shrink: workers exit only here, at a job boundary, never
        // mid-job — the controller lowers `target` and whichever workers
        // win the CAS decrement leave before blocking on the queue.
        loop {
            let size = shard.pool.size.load(Ordering::Relaxed);
            let target = shard.pool.target.load(Ordering::Relaxed);
            if size <= target {
                break;
            }
            if shard
                .pool
                .size
                .compare_exchange(size, size - 1, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                return;
            }
        }
        // Drain pass: block for one job, then opportunistically take up
        // to `coalesce - 1` more already-queued jobs in the same grab
        // (never waiting), so a storm of small appends arrives at the
        // group-forming step together.
        let batch = {
            let rx = lock_ok(&rx);
            let first = match rx.recv() {
                Ok(j) => j,
                Err(_) => return, // channel closed
            };
            let mut batch = vec![first];
            while batch.len() < svc.coalesce {
                match rx.try_recv() {
                    Ok(j) => batch.push(j),
                    Err(_) => break,
                }
            }
            batch
        };
        let rest = if batch.len() >= 2 {
            run_group_pass(&shard, &aggregate, &router, batch, &svc)
        } else {
            batch
        };
        // Whatever did not make the group — multi-sample packets, batch
        // jobs, not-ready or key-mismatched appends — runs the serial
        // path in drain order (group members' sequence numbers were
        // already advanced above, so a leftover append behind a grouped
        // one finds its turn ready).
        for job in rest {
            execute_one(job, &shard, &aggregate, &router, &engine, &svc);
        }
    }
}

/// Run one job through the serial path: panic containment, quarantine,
/// metrics, bounded retention, slot fill (the pre-coalescing worker
/// body, one job at a time).
fn execute_one<T: Real>(
    job: Job<T>,
    shard: &Arc<Shard<T>>,
    aggregate: &ServiceMetrics,
    router: &Router,
    engine: &NatsaEngine<T>,
    svc: &ServiceConfig,
) {
    let Job { id, payload, submitted, slot } = job;
    // Which stream to quarantine if execution panics below.
    let panic_stream = match &payload {
        JobPayload::StreamAppend { stream, .. } => Some(*stream),
        #[cfg(test)]
        JobPayload::Panic { stream, .. } => *stream,
        JobPayload::Batch { .. } => None,
    };
    let mut queue_wait = submitted.elapsed().as_secs_f64();
    let start = Instant::now();
    // Panic containment: a panicking job is a FAILED job, not a dead
    // worker — without this, the panic poisons the shard's mutexes
    // and every later wait/poll/append on the shard panics too.
    let outcome = catch_unwind(AssertUnwindSafe(|| match payload {
        JobPayload::Batch { series, m } => (
            engine
                .compute(&series, m)
                .map(|o| o.profile)
                .map_err(|e| e.to_string()),
            0.0,
        ),
        JobPayload::StreamAppend { stream, samples, seq, fanout } => {
            run_stream_append(shard, aggregate, stream, &samples, seq, fanout, svc)
        }
        #[cfg(test)]
        JobPayload::Panic { stream, seq } => run_injected_panic(shard, stream, seq),
    }));
    let (profile, turn_wait) = match outcome {
        Ok(r) => r,
        Err(cause) => {
            shard.metrics.jobs_panicked.fetch_add(1, Ordering::Relaxed);
            aggregate.jobs_panicked.fetch_add(1, Ordering::Relaxed);
            if let Some(stream) = panic_stream {
                quarantine_stream(shard, aggregate, router, stream);
            }
            (Err(format!("job panicked: {}", panic_message(&*cause))), 0.0)
        }
    };
    queue_wait += turn_wait;
    let exec = (start.elapsed().as_secs_f64() - turn_wait).max(0.0);
    finish_job(shard, aggregate, svc, id, &slot, profile, queue_wait, exec);
}

/// Account one finished job and publish its result: outcome metrics
/// (shard + aggregate), bounded retention bookkeeping, slot fill.
#[allow(clippy::too_many_arguments)]
fn finish_job<T: Real>(
    shard: &Shard<T>,
    aggregate: &ServiceMetrics,
    svc: &ServiceConfig,
    id: u64,
    slot: &JobSlot<JobResult<T>>,
    profile: Result<MatrixProfile<T>, String>,
    queue_wait: f64,
    exec: f64,
) {
    // Failed jobs are finished jobs: they count toward latency and
    // the wait/exec sums too (see ServiceMetrics), on both the shard
    // and the aggregate view.
    let failed = profile.is_err();
    shard.metrics.record_outcome(failed, queue_wait, exec);
    aggregate.record_outcome(failed, queue_wait, exec);
    // Feed the AIMD window the end-to-end latency this caller saw:
    // success under the target grows the window, a breach shrinks it.
    if let Some(adm) = &shard.admission {
        adm.on_outcome(Duration::from_secs_f64((queue_wait + exec).max(0.0)));
        ServiceMetrics::publish_gauge(
            &shard.metrics.cwnd_milli,
            &aggregate.cwnd_milli,
            adm.cwnd_milli(),
        );
    }

    // Bounded retention: count the finished result BEFORE publishing
    // it, so a fast waiter can never consume (and decrement) a result
    // that was not yet counted — `consumed()`'s decrement must always
    // pair with `mark_done`'s increment.  Until `fill` below, nothing
    // can consume the slot; eviction may race ahead of the fill, which
    // only means an unconsumed result aged out at the instant it was
    // produced (waiters already holding the slot still receive it).
    {
        let mut store = lock_ok(&shard.slots);
        store.mark_done(id);
        store.evict(svc.result_cap, svc.result_ttl);
    }
    slot.fill(JobResult {
        id,
        profile,
        queue_wait_s: queue_wait,
        exec_s: exec,
    });
}

/// The cross-stream coalescing pass (see the module docs): pick out of
/// `batch` the single-sample appends that are ready **right now** —
/// their stream exists, it is their turn (`seq == next_seq`), the
/// state lock is free (`try_lock` only: a worker must never block on a
/// turn while holding other streams' locks), and the stream agrees
/// with the group's `(m, excl)` key — and apply them as one shared
/// multi-lane row tile, completing each member's slot individually.
/// Everything else is returned, in drain order, for the serial path.
///
/// Backpressure semantics of a partial group: nothing waits for a
/// fuller group — whatever is ready rides together *now*, the rest
/// runs serially right after.  Coalescing changes batching, never
/// admission (queue bounds and [`SubmitError::Backpressure`] behave
/// exactly as before).
fn run_group_pass<T: Real>(
    shard: &Arc<Shard<T>>,
    aggregate: &ServiceMetrics,
    router: &Router,
    batch: Vec<Job<T>>,
    svc: &ServiceConfig,
) -> Vec<Job<T>> {
    // Resolve candidate streams under one streams-map lock (no state
    // locks yet).
    let entries: Vec<Option<Arc<StreamEntry<T>>>> = {
        let streams = lock_ok(&shard.streams);
        batch
            .iter()
            .map(|j| match &j.payload {
                JobPayload::StreamAppend { stream, samples, .. } if samples.len() == 1 => {
                    streams.get(stream).cloned()
                }
                _ => None,
            })
            .collect()
    };
    // Readiness + key filter.  A second append to an already-locked
    // stream fails its try_lock and falls to the serial path, which
    // runs after the group — order preserved.
    let mut guards: Vec<MutexGuard<'_, StreamState<T>>> = Vec::new();
    let mut member_idx: Vec<usize> = Vec::new();
    let mut key: Option<(usize, usize)> = None;
    for (i, entry) in entries.iter().enumerate() {
        let Some(e) = entry else { continue };
        let JobPayload::StreamAppend { seq, .. } = &batch[i].payload else {
            continue;
        };
        let Some(st) = try_lock_ok(&e.state) else { continue };
        if st.closed || st.moved || st.next_seq != *seq {
            continue;
        }
        let k = (st.session.m(), st.session.exclusion());
        match key {
            None => key = Some(k),
            Some(kk) if kk == k => {}
            Some(_) => continue,
        }
        guards.push(st);
        member_idx.push(i);
    }
    if member_idx.len() < 2 {
        drop(guards);
        return batch;
    }
    let mut by_idx: Vec<Option<Job<T>>> = batch.into_iter().map(Some).collect();
    let members: Vec<Job<T>> = member_idx
        .iter()
        .map(|&i| by_idx[i].take().expect("member indices are distinct"))
        .collect();
    let n = members.len();
    let queue_waits: Vec<f64> = members
        .iter()
        .map(|j| j.submitted.elapsed().as_secs_f64())
        .collect();
    let start = Instant::now();
    // The group apply, panic-contained.  The locks were taken OUTSIDE
    // the closure, so an unwind cannot poison them; on panic every
    // member's state is mid-tile and untrustworthy — quarantine them
    // all (`closed` is set before the locks drop, so no turn-winner
    // can touch the damaged state in between).
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        // Write-ahead, one record per member — the same WAL shape as
        // isolated appends, so replay re-applies identically (state →
        // WAL lock order, as everywhere).
        for j in &members {
            let JobPayload::StreamAppend { stream, samples, seq, .. } = &j.payload else {
                unreachable!("group members are stream appends");
            };
            shard.with_wal(aggregate, |w| w.log_append(*stream, *seq, samples));
        }
        // One shared tile across every member's lane.
        let mut sess: Vec<(&mut StreamSession<T>, T)> = guards
            .iter_mut()
            .zip(&members)
            .map(|(g, j)| {
                let JobPayload::StreamAppend { samples, .. } = &j.payload else {
                    unreachable!("group members are stream appends");
                };
                (&mut g.session, samples[0])
            })
            .collect();
        let report = crate::natsa::append_group(&mut sess);
        drop(sess);
        let widths = member_widths(&report);
        // Per-member completion under the still-held locks: snapshot,
        // seq bump, WAL snapshot cadence, fanout delivery.
        let mut done: Vec<(MatrixProfile<T>, usize)> = Vec::with_capacity(n);
        for ((g, j), &width) in guards.iter_mut().zip(&members).zip(&widths) {
            let JobPayload::StreamAppend { stream, fanout, .. } = &j.payload else {
                unreachable!("group members are stream appends");
            };
            let snapshot = g.session.profile();
            g.next_seq += 1;
            if shard.wal_live() {
                g.unsnapshotted += 1;
                if g.unsnapshotted >= svc.wal_opts.snapshot_every.max(1) {
                    let epoch = g.epoch;
                    let next_seq = g.next_seq;
                    let sess_state = g.session.state();
                    shard.with_wal(aggregate, |w| {
                        w.log_snapshot(*stream, epoch, next_seq, &sess_state)
                    });
                    g.unsnapshotted = 0;
                }
            } else {
                g.unsnapshotted = 0;
            }
            if *fanout {
                let shared = Arc::new(snapshot.clone());
                let delivered = fanout::deliver(&mut g.subs, &shared, svc.result_cap);
                if delivered > 0 {
                    shard.metrics.fanout_delivered.fetch_add(delivered, Ordering::Relaxed);
                    aggregate.fanout_delivered.fetch_add(delivered, Ordering::Relaxed);
                }
            }
            done.push((snapshot, width));
        }
        done
    }));
    match outcome {
        Ok(done) => {
            drop(guards);
            // Wake turn-waiters only after the locks are released.
            for &i in &member_idx {
                entries[i].as_ref().expect("member had an entry").cv.notify_all();
            }
            let exec_share = start.elapsed().as_secs_f64() / n as f64;
            for ((job, (snapshot, width)), qw) in members.into_iter().zip(done).zip(queue_waits) {
                shard.metrics.record_append_width(width);
                aggregate.record_append_width(width);
                finish_job(shard, aggregate, svc, job.id, &job.slot, Ok(snapshot), qw, exec_share);
            }
        }
        Err(cause) => {
            for g in guards.iter_mut() {
                g.closed = true;
            }
            drop(guards);
            let msg = format!("job panicked: {}", panic_message(&*cause));
            let exec_share = start.elapsed().as_secs_f64() / n as f64;
            for (job, qw) in members.into_iter().zip(queue_waits) {
                let JobPayload::StreamAppend { stream, .. } = &job.payload else {
                    unreachable!("group members are stream appends");
                };
                shard.metrics.jobs_panicked.fetch_add(1, Ordering::Relaxed);
                aggregate.jobs_panicked.fetch_add(1, Ordering::Relaxed);
                quarantine_stream(shard, aggregate, router, *stream);
                finish_job(
                    shard,
                    aggregate,
                    svc,
                    job.id,
                    &job.slot,
                    Err(msg.clone()),
                    qw,
                    exec_share,
                );
            }
        }
    }
    by_idx.into_iter().flatten().collect()
}

/// Map a group report's lane-chunk widths back to per-member widths:
/// admitted non-first-window members occupy the kernel lanes in member
/// order (chunked `<= BAND` wide); warm-up and first-window members
/// never entered a shared tile and count as width 1.
fn member_widths(report: &crate::mp::stampi::GroupAppendReport) -> Vec<usize> {
    let mut per_lane: Vec<usize> = Vec::new();
    for &w in &report.widths {
        for _ in 0..w {
            per_lane.push(w);
        }
    }
    let mut lanes = per_lane.into_iter();
    report
        .windows
        .iter()
        .map(|k| match k {
            Some(k) if *k > 0 => lanes.next().unwrap_or(1),
            _ => 1,
        })
        .collect()
}

/// Best-effort panic payload rendering (the common `&str`/`String` cases).
fn panic_message(cause: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = cause.downcast_ref::<&str>() {
        s
    } else if let Some(s) = cause.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// A panic unwound out of this stream's apply path: its in-memory state
/// (mid-`extend`) and turn chain can no longer be trusted.  Remove the
/// stream, fail its turn-waiters (who would otherwise wait for a
/// `next_seq` bump that will never come), and `Close` it in the WAL —
/// replaying the packet that just panicked would only panic again on
/// recovery.
fn quarantine_stream<T: Real>(
    shard: &Shard<T>,
    aggregate: &ServiceMetrics,
    router: &Router,
    stream: u64,
) {
    let entry = lock_ok(&shard.streams).remove(&stream);
    if let Some(e) = entry {
        e.gone.store(true, Ordering::Release);
        let mut st = lock_ok(&e.state);
        if st.moved {
            // A migration committed this entry away before the panic
            // was handled: the stream now lives (healthy) on another
            // shard and this entry is a husk — nothing to retire.
            return;
        }
        // Unroute under the state lock (no CAS: whatever placement the
        // stream reached, it is being retired).  Holding `state` here
        // is what lets the migration commit treat its flip as
        // infallible — every flip-breaker, this one included, needs
        // the lock the migration holds at its commit point.
        router.remove(stream);
        st.closed = true;
        shard.with_wal(aggregate, |w| w.log_close(stream));
        // A quarantined stream drops its subscriptions: its snapshots
        // can no longer be produced, so subscribers see `Closed` (after
        // draining what was already delivered).
        fanout::close_all(&mut st.subs);
        drop(st);
        e.cv.notify_all();
    }
}

/// Test-only injected panic (see [`JobPayload::Panic`]): dies either
/// immediately or after winning the stream's turn while holding its
/// state lock — the worst-case poisoning path the quarantine must cover.
#[cfg(test)]
fn run_injected_panic<T: Real>(
    shard: &Shard<T>,
    stream: Option<u64>,
    seq: u64,
) -> (Result<MatrixProfile<T>, String>, f64) {
    let Some(stream) = stream else {
        panic!("injected panic (test)")
    };
    let entry = lock_ok(&shard.streams).get(&stream).cloned();
    match entry {
        Some(e) => {
            let mut st = lock_ok(&e.state);
            while !st.closed && st.next_seq != seq {
                st = wait_ok(&e.cv, st);
            }
            panic!("injected stream panic (test)");
        }
        None => (Err(format!("unknown or closed stream {stream}")), 0.0),
    }
}

/// Apply one append batch in sequence order and snapshot the profile.
/// The batch rides the engine's blocked row-kernel path (up to BAND
/// samples per tile), and the snapshot pays the one deferred sqrt pass
/// of the squared-profile representation.  Returns the result plus the
/// seconds spent waiting for this append's turn (reported as queueing,
/// not execution).
///
/// Durability ordering (all under the stream's state lock, which is
/// taken BEFORE the shard's WAL lock, never after): log `Append` →
/// apply → maybe log `Snapshot`.  One WAL record per packet means
/// replay re-applies with identical tile boundaries — bit-identical
/// profiles.
fn run_stream_append<T: Real>(
    shard: &Shard<T>,
    aggregate: &ServiceMetrics,
    stream: u64,
    samples: &[T],
    seq: u64,
    fanout: bool,
    svc: &ServiceConfig,
) -> (Result<MatrixProfile<T>, String>, f64) {
    let entry = match lock_ok(&shard.streams).get(&stream).cloned() {
        Some(e) => e,
        None => return (Err(format!("unknown or closed stream {stream}")), 0.0),
    };
    let wait_start = Instant::now();
    let mut state = lock_ok(&entry.state);
    // Appends dequeued out of order (multiple workers) wait their turn;
    // `closed` breaks the wait so close_stream never strands a worker
    // (and `moved` likewise, defensively — migration quiesces at the
    // submit-seq barrier, so every append admitted against this entry
    // applies *before* the commit sets `moved`; see
    // `crate::coordinator::migrate`).
    while !state.closed && !state.moved && state.next_seq != seq {
        state = wait_ok(&entry.cv, state);
    }
    let turn_wait = wait_start.elapsed().as_secs_f64();
    if state.closed {
        return (Err(format!("stream {stream} closed")), turn_wait);
    }
    if state.moved {
        // Unreachable by the quiesce barrier (see above); failing the
        // job loudly beats applying it to a stale session.
        return (Err(format!("stream {stream} migrated mid-append")), turn_wait);
    }
    // Write-ahead: the packet is durable before it is applied — a crash
    // in between replays the packet instead of losing it.
    shard.with_wal(aggregate, |w| w.log_append(stream, seq, samples));
    state.session.extend(samples);
    let snapshot = state.session.profile();
    state.next_seq += 1;
    // This append ran the serial path: width 1 in the coalescing story
    // (the group pass records the lane width its members actually rode).
    shard.metrics.record_append_width(1);
    aggregate.record_append_width(1);
    if fanout {
        let shared = Arc::new(snapshot.clone());
        let delivered = fanout::deliver(&mut state.subs, &shared, svc.result_cap);
        if delivered > 0 {
            shard.metrics.fanout_delivered.fetch_add(delivered, Ordering::Relaxed);
            aggregate.fanout_delivered.fetch_add(delivered, Ordering::Relaxed);
        }
    }
    // Snapshot cadence only ticks while the WAL is live — with it off
    // (or disabled by an earlier write error) the counter stays 0, as
    // its doc promises, instead of counting toward u32 overflow and
    // periodically paying for a deep `session.state()` copy that
    // `with_wal` would silently discard.
    if shard.wal_live() {
        state.unsnapshotted += 1;
        if state.unsnapshotted >= svc.wal_opts.snapshot_every.max(1) {
            let epoch = state.epoch;
            let next_seq = state.next_seq;
            let sess_state = state.session.state();
            shard.with_wal(aggregate, |w| w.log_snapshot(stream, epoch, next_seq, &sess_state));
            state.unsnapshotted = 0;
        }
    } else {
        state.unsnapshotted = 0;
    }
    entry.cv.notify_all();
    (Ok(snapshot), turn_wait)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{stomp, MpConfig};
    use crate::prop::Rng;
    use crate::timeseries::generator::{generate, Pattern};

    fn svc() -> AnalysisService<f64> {
        AnalysisService::start(NatsaConfig::default().with_threads(2), 2, 4)
    }

    /// Spin until the aggregate view shows nothing in flight.
    fn drain(s: &AnalysisService<f64>) {
        let deadline = Instant::now()
            .checked_add(Duration::from_secs(30))
            .expect("deadline representable");
        while s.metrics().in_flight() > 0 {
            assert!(Instant::now() < deadline, "service never drained");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let s = svc();
        let series = Arc::new(generate::<f64>(Pattern::PlantedMotif, 1024, 3));
        let id = s.submit(series, 32).unwrap();
        let r = s.wait(id).unwrap();
        let profile = r.profile.unwrap();
        assert_eq!(profile.len(), 1024 - 32 + 1);
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 1);
        // consuming the result freed its slot
        assert_eq!(s.retained_results(), 0);
        s.shutdown();
    }

    #[test]
    fn many_jobs_from_many_clients() {
        let s = Arc::new(AnalysisService::<f64>::start(
            NatsaConfig::default().with_threads(1),
            3,
            64,
        ));
        let mut ids = Vec::new();
        for k in 0..12 {
            let series = Arc::new(generate::<f64>(Pattern::RandomWalk, 512, k));
            ids.push(s.submit(series, 16).unwrap());
        }
        for id in ids {
            let r = s.wait(id).unwrap();
            assert!(r.profile.is_ok());
        }
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 12);
        assert_eq!(s.metrics().in_flight(), 0);
        assert_eq!(s.retained_results(), 0);
    }

    #[test]
    fn bad_job_reports_error_not_panic() {
        let s = svc();
        let id = s.submit(Arc::new(vec![1.0f64; 9]), 8).unwrap(); // nw(2) <= excl(2)
        let r = s.wait(id).unwrap();
        assert!(r.profile.is_err());
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), 1);
        s.shutdown();
    }

    #[test]
    fn failed_jobs_show_up_in_latency_metrics() {
        // regression: failed jobs ticked only jobs_failed, leaving the
        // latency histogram and wait/exec sums blind under error load
        let s = svc();
        let id = s.submit(Arc::new(vec![1.0f64; 9]), 8).unwrap();
        let r = s.wait(id).unwrap();
        assert!(r.profile.is_err());
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics().latency.count(), 1, "failed job missing from histogram");
        assert_eq!(s.metrics().finished(), 1);
        assert_eq!(s.metrics().in_flight(), 0);
        s.shutdown();
    }

    #[test]
    fn wait_on_unknown_id_errors_instead_of_blocking() {
        // regression: wait() used to block forever on an id that was
        // never enqueued (rejected submit) or was already consumed
        let s = svc();
        assert_eq!(s.wait(0xdead_beef).unwrap_err(), WaitError::Unknown);
        let id = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 1)), 16).unwrap();
        assert!(s.wait(id).is_ok());
        // second wait on the same id: consumed, not a hang
        assert_eq!(s.wait(id).unwrap_err(), WaitError::Unknown);
        assert!(s.poll(id).is_none());
        s.shutdown();
    }

    #[test]
    fn wait_timeout_gives_up_and_can_retry() {
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 4);
        let mut rng = Rng::new(11);
        let series = Arc::new(rng.gauss_vec(20_000));
        let id = s.submit(series, 16).unwrap();
        // far too short for a 20k-sample profile: must time out, not hang
        assert_eq!(
            s.wait_timeout(id, Duration::from_micros(10)).unwrap_err(),
            WaitError::Timeout
        );
        // the job is still in flight; a real wait gets the result
        let r = s.wait(id).unwrap();
        assert!(r.profile.is_ok());
        s.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, queue depth 1, slow-ish jobs: the 3rd+ submit in a
        // tight loop must eventually see Backpressure.
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 1);
        let mut rng = Rng::new(9);
        let series = Arc::new(rng.gauss_vec(6000));
        let mut saw_backpressure = false;
        let mut accepted = Vec::new();
        for _ in 0..32 {
            match s.submit(series.clone(), 16) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::Backpressure) => {
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_backpressure, "queue never filled");
        for id in accepted {
            let _ = s.wait(id).unwrap();
        }
        assert!(s.metrics().jobs_rejected.load(Ordering::Relaxed) >= 1);
        s.shutdown();
    }

    #[test]
    fn fire_and_forget_results_are_bounded() {
        // regression: unconsumed JobResults used to accumulate forever;
        // the per-shard retention cap must bound them
        let s = AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_workers(1)
                .with_queue_depth(32)
                .with_result_cap(4),
        );
        let mut ids = Vec::new();
        for k in 0..16 {
            let series = Arc::new(generate::<f64>(Pattern::RandomWalk, 256, k));
            ids.push(s.submit(series, 16).unwrap()); // never waited on
        }
        drain(&s);
        // one more enqueue triggers a final eviction pass
        let last = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 99)), 16).unwrap();
        let _ = s.wait(last).unwrap();
        assert!(
            s.retained_results() <= 4,
            "retained {} results, cap 4",
            s.retained_results()
        );
        // evicted ids answer Unknown, they don't hang
        assert_eq!(s.wait(ids[0]).unwrap_err(), WaitError::Unknown);
        s.shutdown();
    }

    #[test]
    fn result_ttl_expires_unconsumed_results() {
        let s = AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default()
                .with_workers(1)
                .with_result_ttl(Duration::from_millis(20)),
        );
        let id = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 1)), 16).unwrap();
        drain(&s);
        std::thread::sleep(Duration::from_millis(40));
        // a later enqueue runs the eviction pass; the stale result is gone
        let fresh = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 2)), 16).unwrap();
        assert!(s.wait(fresh).is_ok());
        assert_eq!(s.wait(id).unwrap_err(), WaitError::Unknown);
        assert_eq!(s.retained_results(), 0);
        s.shutdown();
    }

    #[test]
    fn shutdown_closes_submission() {
        let s = svc();
        let aggregate = s.aggregate.clone();
        s.shutdown();
        // after shutdown the channels are gone; metrics survive
        assert_eq!(aggregate.in_flight(), 0);
    }

    #[test]
    fn stream_appends_match_batch_profile() {
        let s = svc();
        let series = generate::<f64>(Pattern::EcgLike, 2048, 8);
        let m = 32;
        let stream = s.submit_stream(m, None).unwrap();
        // feed in uneven batches, awaiting each append (ordered by client)
        let mut last = None;
        for chunk in series.chunks(300) {
            let id = s.append_stream(stream, chunk).unwrap();
            last = Some(s.wait(id).unwrap());
        }
        let streamed = last.unwrap().profile.unwrap();
        let want = stomp::matrix_profile(&series, MpConfig::new(m)).unwrap();
        assert_eq!(streamed.len(), want.len());
        assert!(
            streamed.max_abs_diff(&want) < 1e-6,
            "{}",
            streamed.max_abs_diff(&want)
        );
        // the live snapshot agrees with the last append's result
        let snap = s.snapshot_stream(stream).unwrap();
        assert!(snap.max_abs_diff(&streamed) < 1e-15);
        assert!(s.close_stream(stream));
        s.shutdown();
    }

    #[test]
    fn stream_appends_are_applied_in_order_across_workers() {
        // 3 workers racing on one stream: per-stream sequencing must keep
        // the profile equal to the in-order batch run even though jobs are
        // all enqueued before any completes.
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 3, 64);
        let series = generate::<f64>(Pattern::RandomWalk, 3000, 9);
        let m = 16;
        let stream = s.submit_stream(m, None).unwrap();
        let mut ids = Vec::new();
        for chunk in series.chunks(128) {
            ids.push(s.append_stream(stream, chunk).unwrap());
        }
        for id in ids {
            assert!(s.wait(id).unwrap().profile.is_ok());
        }
        let got = s.snapshot_stream(stream).unwrap();
        let want = stomp::matrix_profile(&series, MpConfig::new(m)).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn pipelined_append_consumes_oldest_acks_under_backpressure() {
        // tiny queue, 1 worker: the shared feeding loop must keep making
        // progress by draining its own acks, delivering every result
        // exactly once
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 2);
        let series = generate::<f64>(Pattern::RandomWalk, 2000, 12);
        let m = 16;
        let stream = s.submit_stream(m, None).unwrap();
        let mut pending = VecDeque::new();
        let mut seen = 0usize;
        for packet in series.chunks(100) {
            let (_, drained) = s
                .append_stream_pipelined(stream, packet, &mut pending)
                .unwrap();
            for r in &drained {
                assert!(r.profile.is_ok());
            }
            seen += drained.len();
        }
        for id in pending {
            assert!(s.wait(id).unwrap().profile.is_ok());
            seen += 1;
        }
        assert_eq!(seen, 20); // 2000 / 100 appends, each consumed once
        let got = s.snapshot_stream(stream).unwrap();
        let want = stomp::matrix_profile(&series, MpConfig::new(m)).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-7, "{}", got.max_abs_diff(&want));
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn append_to_unknown_stream_is_rejected() {
        let s = svc();
        assert_eq!(
            s.append_stream(999, &[1.0, 2.0]),
            Err(SubmitError::UnknownStream)
        );
        s.shutdown();
    }

    #[test]
    fn closed_stream_fails_pending_and_future_appends() {
        let s = svc();
        let stream = s.submit_stream(16, None).unwrap();
        let id = s.append_stream(stream, &generate::<f64>(Pattern::RandomWalk, 64, 1)).unwrap();
        let _ = s.wait(id).unwrap();
        assert!(s.close_stream(stream));
        assert!(!s.close_stream(stream)); // idempotent: already gone
        assert_eq!(
            s.append_stream(stream, &[1.0]),
            Err(SubmitError::UnknownStream)
        );
        assert!(s.snapshot_stream(stream).is_none());
        s.shutdown();
    }

    #[test]
    fn stream_with_bounded_history_reports_suffix_profile() {
        let s = svc();
        let m = 16;
        let stream = s.submit_stream(m, Some(256)).unwrap();
        let series = generate::<f64>(Pattern::RandomWalk, 2000, 10);
        let id = s.append_stream(stream, &series).unwrap();
        let snap = s.wait(id).unwrap().profile.unwrap();
        assert_eq!(snap.len(), 256 - m + 1);
        // a bound too small to admit a pair is rejected at open time
        assert!(matches!(
            s.submit_stream(16, Some(8)),
            Err(SubmitError::Invalid(_))
        ));
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn batch_and_stream_jobs_share_metrics() {
        let s = svc();
        let stream = s.submit_stream(16, None).unwrap();
        let a = s.append_stream(stream, &generate::<f64>(Pattern::RandomWalk, 200, 2)).unwrap();
        let b = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 3)), 16).unwrap();
        let _ = s.wait(a).unwrap();
        let _ = s.wait(b).unwrap();
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 2);
        assert_eq!(s.metrics().in_flight(), 0);
        s.close_stream(stream);
        s.shutdown();
    }

    #[test]
    fn streams_route_stably_across_shards() {
        let s = AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default().with_shards(4).with_workers(1).with_queue_depth(16),
        );
        assert_eq!(s.num_shards(), 4);
        let mut hit = [false; 4];
        let mut streams = Vec::new();
        for _ in 0..32 {
            let id = s.submit_stream(16, None).unwrap();
            let home = s.stream_home(id).expect("fresh stream is routed");
            assert!(home < 4);
            // at mint — and only then — the packed hint and the router
            // agree by construction
            assert_eq!(shard_of(id), home, "mint-time hint disagrees with router");
            hit[home] = true;
            streams.push(id);
        }
        assert!(
            hit.iter().filter(|&&h| h).count() >= 3,
            "hash routing left shards cold: {hit:?}"
        );
        // every append job lands on its stream's current home shard
        for &stream in streams.iter().take(6) {
            let id = s.append_stream(stream, &generate::<f64>(Pattern::RandomWalk, 128, 4)).unwrap();
            assert_eq!(
                shard_of(id),
                s.stream_home(stream).unwrap(),
                "append left its stream's shard"
            );
            assert!(s.wait(id).unwrap().profile.is_ok());
        }
        // aggregate reconciles with the per-shard counters
        let per_shard: u64 = (0..4)
            .map(|k| s.shard_metrics(k).jobs_completed.load(Ordering::Relaxed))
            .sum();
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), per_shard);
        for stream in streams {
            s.close_stream(stream);
        }
        s.shutdown();
    }

    #[test]
    fn panicking_job_fails_cleanly_and_shard_survives() {
        // regression: a worker panic used to poison the shard's slot
        // mutex, turning every later wait/poll/submit on the shard into
        // a cascade of panics.  Now the job fails, the panic is counted,
        // and the shard keeps serving.
        let s = svc();
        let id = s.submit_panic().unwrap();
        let r = s.wait(id).unwrap();
        let err = r.profile.unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        assert_eq!(s.metrics().jobs_panicked.load(Ordering::Relaxed), 1);
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), 1);
        // the same shard still runs normal work afterwards
        let id = s.submit(Arc::new(generate::<f64>(Pattern::PlantedMotif, 512, 4)), 16).unwrap();
        assert!(s.wait(id).unwrap().profile.is_ok());
        assert_eq!(s.metrics().in_flight(), 0);
        assert_eq!(s.retained_results(), 0);
        s.shutdown();
    }

    #[test]
    fn stream_panic_quarantines_stream_but_not_shard() {
        // worst-case poisoning: the injected job panics while HOLDING the
        // stream's state lock, with another append turn-waiting behind it
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 2, 16);
        let a = s.submit_stream(16, None).unwrap();
        let b = s.submit_stream(16, None).unwrap();
        let id = s.append_stream(a, &generate::<f64>(Pattern::RandomWalk, 200, 1)).unwrap();
        assert!(s.wait(id).unwrap().profile.is_ok());
        let bad = s.append_stream_panic(a).unwrap();
        let behind = s.append_stream(a, &[1.0, 2.0, 3.0]).unwrap();
        let err = s.wait(bad).unwrap().profile.unwrap_err();
        assert!(err.contains("panicked"), "{err}");
        // quarantine: the queued append fails (not strands), new appends
        // and snapshots see the stream gone
        assert!(s.wait(behind).unwrap().profile.is_err());
        assert_eq!(s.append_stream(a, &[1.0]), Err(SubmitError::UnknownStream));
        assert!(s.snapshot_stream(a).is_none());
        assert_eq!(s.metrics().jobs_panicked.load(Ordering::Relaxed), 1);
        // the sibling stream on the same shard is untouched
        let id = s.append_stream(b, &generate::<f64>(Pattern::RandomWalk, 200, 2)).unwrap();
        assert!(s.wait(id).unwrap().profile.is_ok());
        assert!(s.close_stream(b));
        assert_eq!(s.metrics().in_flight(), 0);
        s.shutdown();
    }

    #[test]
    fn quarantined_stream_drops_its_subscriptions() {
        // a panic-quarantined stream can never produce snapshots again:
        // its subscribers must drain what was delivered, then see Closed
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 16);
        let a = s.submit_stream(16, None).unwrap();
        let id = s.append_stream(a, &generate::<f64>(Pattern::RandomWalk, 64, 5)).unwrap();
        assert!(s.wait(id).unwrap().profile.is_ok());
        let sub = s.subscribe_stream(a).unwrap();
        let id = s.append_stream_fanout(a, &[0.25]).unwrap();
        assert!(s.wait(id).unwrap().profile.is_ok());
        assert_eq!(s.metrics().fanout_delivered.load(Ordering::Relaxed), 1);
        let bad = s.append_stream_panic(a).unwrap();
        assert!(s.wait(bad).unwrap().profile.is_err());
        // the pre-quarantine delivery drains, then the box reports Closed
        assert!(matches!(s.poll_subscription(sub), SubRecv::Snapshot(_)));
        assert!(matches!(s.poll_subscription(sub), SubRecv::Closed));
        // and a fresh fanout append can no longer deliver anywhere
        assert_eq!(s.append_stream_fanout(a, &[1.0]), Err(SubmitError::UnknownStream));
        assert_eq!(s.metrics().fanout_delivered.load(Ordering::Relaxed), 1);
        assert!(s.unsubscribe(sub), "box stays registered until unsubscribed");
        assert!(matches!(s.poll_subscription(sub), SubRecv::Closed));
        s.shutdown();
    }

    #[test]
    fn wait_timeout_tiny_budgets_under_contention_never_panic() {
        // regression: a wakeup landing PAST the deadline computed
        // `deadline - now` and underflowed `Instant`; `Duration::MAX`
        // overflowed `now + timeout`.  Both must degrade, not panic.
        let s = Arc::new(AnalysisService::<f64>::start(
            NatsaConfig::default().with_threads(1),
            1,
            4,
        ));
        let mut rng = Rng::new(21);
        let id = s.submit(Arc::new(rng.gauss_vec(20_000)), 16).unwrap();
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                std::thread::spawn(move || {
                    for k in 0..200u64 {
                        match s.wait_timeout(id, Duration::from_nanos(k % 3)) {
                            Err(WaitError::Timeout) | Err(WaitError::Unknown) => {}
                            Ok(_) => break, // consumed it first — fine
                        }
                    }
                })
            })
            .collect();
        for w in waiters {
            w.join().unwrap();
        }
        // overflow-proof: an effectively-infinite timeout is an untimed wait
        match s.wait_timeout(id, Duration::MAX) {
            Ok(r) => assert!(r.profile.is_ok()),
            Err(WaitError::Unknown) => {} // a racing waiter consumed it
            Err(WaitError::Timeout) => panic!("Duration::MAX timed out"),
        }
    }

    #[test]
    fn close_rejects_in_flight_pipelined_appends() {
        // reject-not-drain: appends queued (pipelined) when close_stream
        // runs must FAIL, not apply after the close
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 256);
        let stream = s.submit_stream(16, None).unwrap();
        let series = generate::<f64>(Pattern::RandomWalk, 8000, 7);
        let mut ids = Vec::new();
        for chunk in series.chunks(50) {
            ids.push(s.append_stream(stream, chunk).unwrap());
        }
        assert!(s.close_stream(stream));
        let (mut applied, mut rejected) = (0usize, 0usize);
        for id in ids {
            match s.wait(id).unwrap().profile {
                Ok(_) => applied += 1,
                Err(e) => {
                    assert!(e.contains("closed"), "{e}");
                    rejected += 1;
                }
            }
        }
        assert!(rejected > 0, "close drained {applied} queued appends instead of rejecting");
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), rejected as u64);
        assert_eq!(s.metrics().in_flight(), 0);
        s.shutdown();
    }

    #[test]
    fn shard_config_invariants() {
        // shard count is clamped, ids round-trip their shard
        let s = AnalysisService::<f64>::start_sharded(
            NatsaConfig::default().with_threads(1),
            ServiceConfig::default().with_shards(0).with_workers(1),
        );
        assert_eq!(s.num_shards(), 1);
        let id = s.submit(Arc::new(generate::<f64>(Pattern::RandomWalk, 256, 5)), 16).unwrap();
        assert_eq!(shard_of(id), 0);
        assert!(s.wait(id).is_ok());
        s.shutdown();
    }
}
