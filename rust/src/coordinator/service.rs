//! The analysis service: a multi-client job queue over the NATSA engine.
//!
//! The accelerator itself computes one profile at a time per PU fleet;
//! a deployment wraps it in a service that accepts jobs from many clients,
//! applies backpressure when the queue is full, and reports metrics —
//! the same role the vLLM router plays for model replicas.  Workers run
//! the *native* functional engine by default (fast path); the PJRT engine
//! is exercised by the end-to-end example and integration tests.
//!
//! Design notes:
//! * `std::sync::mpsc` + worker threads (tokio is not in the offline
//!   vendor set; the queue semantics are identical for this shape),
//! * bounded queue => `submit` fails fast with [`SubmitError::Backpressure`]
//!   instead of buffering unboundedly,
//! * each job may carry its own window length and precision is fixed by
//!   the service's type parameter.

use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Condvar, Mutex};

use crate::coordinator::metrics::ServiceMetrics;
use crate::mp::MatrixProfile;
use crate::natsa::{NatsaConfig, NatsaEngine};
use crate::Real;

/// A submitted analysis job.
struct Job<T> {
    id: u64,
    series: Arc<Vec<T>>,
    m: usize,
    submitted: std::time::Instant,
}

/// Completed job result.
#[derive(Clone, Debug)]
pub struct JobResult<T> {
    pub id: u64,
    pub profile: Result<MatrixProfile<T>, String>,
    pub queue_wait_s: f64,
    pub exec_s: f64,
}

/// Why a submission was rejected.
#[derive(Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue full — caller should retry later (backpressure).
    Backpressure,
    /// Service is shutting down.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Backpressure => write!(f, "queue full (backpressure)"),
            SubmitError::Closed => write!(f, "service closed"),
        }
    }
}

struct Shared<T> {
    results: Mutex<HashMap<u64, JobResult<T>>>,
    cv: Condvar,
    metrics: ServiceMetrics,
}

/// Multi-worker analysis service over the functional NATSA engine.
pub struct AnalysisService<T: Real> {
    tx: Option<SyncSender<Job<T>>>,
    shared: Arc<Shared<T>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: std::sync::atomic::AtomicU64,
}

impl<T: Real> AnalysisService<T> {
    /// Start `workers` worker threads with a bounded queue of `depth`.
    pub fn start(config: NatsaConfig, workers: usize, depth: usize) -> Self {
        let (tx, rx) = sync_channel::<Job<T>>(depth);
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            results: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            metrics: ServiceMetrics::default(),
        });
        let mut handles = Vec::new();
        for _ in 0..workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            handles.push(std::thread::spawn(move || {
                worker_loop(rx, shared, config);
            }));
        }
        AnalysisService {
            tx: Some(tx),
            shared,
            workers: handles,
            next_id: std::sync::atomic::AtomicU64::new(1),
        }
    }

    /// Submit a job; fails fast under backpressure.
    pub fn submit(&self, series: Arc<Vec<T>>, m: usize) -> Result<u64, SubmitError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let job = Job {
            id,
            series,
            m,
            submitted: std::time::Instant::now(),
        };
        match self.tx.as_ref().ok_or(SubmitError::Closed)?.try_send(job) {
            Ok(()) => {
                self.shared
                    .metrics
                    .jobs_submitted
                    .fetch_add(1, Ordering::Relaxed);
                Ok(id)
            }
            Err(TrySendError::Full(_)) => {
                self.shared
                    .metrics
                    .jobs_rejected
                    .fetch_add(1, Ordering::Relaxed);
                Err(SubmitError::Backpressure)
            }
            Err(TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    /// Block until job `id` completes.
    pub fn wait(&self, id: u64) -> JobResult<T> {
        let mut results = self.shared.results.lock().unwrap();
        loop {
            if let Some(r) = results.remove(&id) {
                return r;
            }
            results = self.shared.cv.wait(results).unwrap();
        }
    }

    /// Non-blocking poll.
    pub fn poll(&self, id: u64) -> Option<JobResult<T>> {
        self.shared.results.lock().unwrap().remove(&id)
    }

    pub fn metrics(&self) -> &ServiceMetrics {
        &self.shared.metrics
    }

    /// Stop accepting jobs, drain the queue, join workers.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop<T: Real>(
    rx: Arc<Mutex<Receiver<Job<T>>>>,
    shared: Arc<Shared<T>>,
    config: NatsaConfig,
) {
    let engine = NatsaEngine::<T>::new(config);
    loop {
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => return, // channel closed
        };
        let queue_wait = job.submitted.elapsed().as_secs_f64();
        let start = std::time::Instant::now();
        let outcome = engine.compute(&job.series, job.m);
        let exec = start.elapsed().as_secs_f64();

        let (profile, failed) = match outcome {
            Ok(o) => (Ok(o.profile), false),
            Err(e) => (Err(e.to_string()), true),
        };
        let m = &shared.metrics;
        if failed {
            m.jobs_failed.fetch_add(1, Ordering::Relaxed);
        } else {
            m.jobs_completed.fetch_add(1, Ordering::Relaxed);
            m.exec_ns
                .fetch_add((exec * 1e9) as u64, Ordering::Relaxed);
            m.queue_wait_ns
                .fetch_add((queue_wait * 1e9) as u64, Ordering::Relaxed);
            m.latency.record(queue_wait + exec);
        }
        shared.results.lock().unwrap().insert(
            job.id,
            JobResult {
                id: job.id,
                profile,
                queue_wait_s: queue_wait,
                exec_s: exec,
            },
        );
        shared.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;
    use crate::timeseries::generator::{generate, Pattern};

    fn svc() -> AnalysisService<f64> {
        AnalysisService::start(NatsaConfig::default().with_threads(2), 2, 4)
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let s = svc();
        let series = Arc::new(generate::<f64>(Pattern::PlantedMotif, 1024, 3));
        let id = s.submit(series, 32).unwrap();
        let r = s.wait(id);
        let profile = r.profile.unwrap();
        assert_eq!(profile.len(), 1024 - 32 + 1);
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 1);
        s.shutdown();
    }

    #[test]
    fn many_jobs_from_many_clients() {
        let s = Arc::new(AnalysisService::<f64>::start(
            NatsaConfig::default().with_threads(1),
            3,
            64,
        ));
        let mut ids = Vec::new();
        for k in 0..12 {
            let series = Arc::new(generate::<f64>(Pattern::RandomWalk, 512, k));
            ids.push(s.submit(series, 16).unwrap());
        }
        for id in ids {
            let r = s.wait(id);
            assert!(r.profile.is_ok());
        }
        assert_eq!(s.metrics().jobs_completed.load(Ordering::Relaxed), 12);
        assert_eq!(s.metrics().in_flight(), 0);
    }

    #[test]
    fn bad_job_reports_error_not_panic() {
        let s = svc();
        let id = s.submit(Arc::new(vec![1.0f64; 9]), 8).unwrap(); // nw(2) <= excl(2)
        let r = s.wait(id);
        assert!(r.profile.is_err());
        assert_eq!(s.metrics().jobs_failed.load(Ordering::Relaxed), 1);
        s.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // 1 worker, queue depth 1, slow-ish jobs: the 3rd+ submit in a
        // tight loop must eventually see Backpressure.
        let s = AnalysisService::<f64>::start(NatsaConfig::default().with_threads(1), 1, 1);
        let mut rng = Rng::new(9);
        let series = Arc::new(rng.gauss_vec(6000));
        let mut saw_backpressure = false;
        let mut accepted = Vec::new();
        for _ in 0..32 {
            match s.submit(series.clone(), 16) {
                Ok(id) => accepted.push(id),
                Err(SubmitError::Backpressure) => {
                    saw_backpressure = true;
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(saw_backpressure, "queue never filled");
        for id in accepted {
            let _ = s.wait(id);
        }
        assert!(s.metrics().jobs_rejected.load(Ordering::Relaxed) >= 1);
        s.shutdown();
    }

    #[test]
    fn shutdown_closes_submission() {
        let s = svc();
        let shared = s.shared.clone();
        s.shutdown();
        // after shutdown the channel is gone; metrics survive
        assert_eq!(shared.metrics.in_flight(), 0);
    }
}
