//! Per-job completion slots and their bounded retention store.
//!
//! Extracted from the service so the protocol is a small, generic,
//! directly-testable unit: `rust/tests/loom_service.rs` model-checks
//! exactly these types (reserve → fill → take vs. eviction vs.
//! timeout) under loom, and the service instantiates them with
//! `R = JobResult<T>`.
//!
//! ## Slot lifecycle
//!
//! ```text
//!          reserve            fill                take / try_take
//! (absent) ───────► Pending ───────► Done(result) ───────────────► Consumed
//!     ▲                                   │
//!     └─────────── evict (cap/ttl) ◄──────┘        (map entry removed)
//! ```
//!
//! * `fill` happens exactly once (worker side) and wakes every waiter;
//! * `take` consumes exactly once — a second taker finds `Consumed`
//!   and reports [`TakeError::Consumed`] instead of blocking;
//! * eviction only ever removes **finished** results (`Pending` slots
//!   are never evicted), so a waiter can always distinguish "still in
//!   flight" from "gone";
//! * a waiter already holding the slot `Arc` when eviction strikes
//!   still receives the result — eviction drops the store's reference,
//!   not the slot.
//!
//! Lock order: the store lock (`Mutex<SlotStore>`) and a slot's own
//! state lock are never held together by this module — callers take
//! the store lock to look a slot up, drop it, then wait on the slot.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use crate::sync::{lock_ok, wait_ok, wait_timeout_ok, Arc, Condvar, Mutex};

/// Why [`JobSlot::take`] returned no result.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TakeError {
    /// A racing take consumed the result first (or it was already
    /// consumed earlier) — the slot will never hold a result again.
    Consumed,
    /// The deadline passed while the slot was still `Pending`; the
    /// result is still coming and can be waited on again.
    Timeout,
}

/// Per-job completion slot: reserved at submit, filled once by a
/// worker, consumed exactly once by `wait`/`poll`.
pub struct JobSlot<R> {
    state: Mutex<SlotState<R>>,
    cv: Condvar,
}

enum SlotState<R> {
    Pending,
    Done(R),
    Consumed,
}

impl<R> JobSlot<R> {
    pub fn new() -> Arc<Self> {
        Arc::new(JobSlot { state: Mutex::new(SlotState::Pending), cv: Condvar::new() })
    }

    /// Worker-side: publish the result and wake every waiter.
    pub fn fill(&self, result: R) {
        let mut state = lock_ok(&self.state);
        *state = SlotState::Done(result);
        drop(state);
        self.cv.notify_all();
    }

    /// Block until the slot is filled and consume the result; with a
    /// deadline, give up with [`TakeError::Timeout`] once it passes.
    ///
    /// Spurious-wakeup-robust: every iteration re-checks the slot state
    /// first and only then recomputes the remaining budget —
    /// saturating, so a wakeup that lands *past* the deadline yields a
    /// clean timeout instead of an `Instant` underflow panic.
    pub fn take(&self, deadline: Option<Instant>) -> Result<R, TakeError> {
        let mut state = lock_ok(&self.state);
        loop {
            match &*state {
                SlotState::Done(_) => break,
                SlotState::Consumed => return Err(TakeError::Consumed),
                SlotState::Pending => {}
            }
            state = match deadline {
                None => wait_ok(&self.cv, state),
                Some(dl) => {
                    let left = dl.saturating_duration_since(Instant::now());
                    if left.is_zero() {
                        return Err(TakeError::Timeout);
                    }
                    wait_timeout_ok(&self.cv, state, left).0
                }
            };
        }
        match std::mem::replace(&mut *state, SlotState::Consumed) {
            SlotState::Done(result) => Ok(result),
            _ => unreachable!("checked Done above"),
        }
    }

    /// Non-blocking take: `Some` exactly once, when the slot is `Done`.
    pub fn try_take(&self) -> Option<R> {
        let mut state = lock_ok(&self.state);
        if !matches!(&*state, SlotState::Done(_)) {
            return None;
        }
        match std::mem::replace(&mut *state, SlotState::Consumed) {
            SlotState::Done(result) => Some(result),
            _ => unreachable!("checked Done above"),
        }
    }
}

/// One shard's slot registry: every live slot (pending + finished) plus
/// the finished-but-unconsumed ids in completion order, so retention
/// can be bounded by count and by age.
pub struct SlotStore<R> {
    map: HashMap<u64, Arc<JobSlot<R>>>,
    /// Finished ids in completion order (may contain ids since
    /// consumed; those are skipped during eviction).
    done: VecDeque<(u64, Instant)>,
    /// Finished-and-still-retained results (the number the cap bounds).
    retained: usize,
}

impl<R> Default for SlotStore<R> {
    fn default() -> Self {
        Self::new()
    }
}

impl<R> SlotStore<R> {
    pub fn new() -> Self {
        SlotStore { map: HashMap::new(), done: VecDeque::new(), retained: 0 }
    }

    /// Reserve a fresh `Pending` slot for `id` and return it.
    pub fn reserve(&mut self, id: u64) -> Arc<JobSlot<R>> {
        let slot = JobSlot::new();
        self.map.insert(id, slot.clone());
        slot
    }

    /// Roll back a reservation whose enqueue was rejected.
    pub fn forget(&mut self, id: u64) {
        self.map.remove(&id);
    }

    /// Look up a live slot (pending or finished-unconsumed).
    pub fn get(&self, id: u64) -> Option<Arc<JobSlot<R>>> {
        self.map.get(&id).cloned()
    }

    /// Record that `id`'s slot was (or is about to be) filled, entering
    /// it into the bounded retention bookkeeping.  Must be called
    /// BEFORE the matching [`JobSlot::fill`], so a fast waiter can
    /// never consume (and decrement) a result that was not yet counted
    /// — [`Self::consumed`]'s decrement must always pair with this
    /// increment.
    pub fn mark_done(&mut self, id: u64) {
        if self.map.contains_key(&id) {
            self.done.push_back((id, Instant::now()));
            self.retained += 1;
        }
    }

    /// Drop finished results beyond `cap` (oldest first) or older than
    /// `ttl`.  Pending jobs are never evicted.
    pub fn evict(&mut self, cap: usize, ttl: Option<Duration>) {
        while let Some(&(id, at)) = self.done.front() {
            if !self.map.contains_key(&id) {
                // consumed by wait/poll already: stale bookkeeping
                self.done.pop_front();
                continue;
            }
            let over_cap = self.retained > cap;
            let expired = ttl.is_some_and(|limit| at.elapsed() >= limit);
            if over_cap || expired {
                self.done.pop_front();
                self.map.remove(&id);
                self.retained = self.retained.saturating_sub(1);
            } else {
                break;
            }
        }
        // An old-but-unevictable result at the front would otherwise
        // shield every stale (consumed) entry behind it forever; compact
        // so the bookkeeping stays O(retained), amortized O(1) per job.
        if self.done.len() > 2 * self.retained + 16 {
            self.done.retain(|&(id, _)| self.map.contains_key(&id));
        }
    }

    /// Consume (remove) `id`'s slot after its result was taken.
    pub fn consumed(&mut self, id: u64) {
        if self.map.remove(&id).is_some() {
            self.retained = self.retained.saturating_sub(1);
        }
    }

    /// Live slots (in-flight jobs plus finished-but-unconsumed results).
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn fill_then_take_roundtrip() {
        let mut store: SlotStore<u32> = SlotStore::new();
        let slot = store.reserve(7);
        assert_eq!(store.len(), 1);
        assert!(slot.try_take().is_none(), "pending slot yields nothing");
        store.mark_done(7);
        slot.fill(42);
        assert_eq!(slot.take(None), Ok(42));
        assert_eq!(slot.take(None), Err(TakeError::Consumed));
        store.consumed(7);
        assert_eq!(store.len(), 0);
    }

    #[test]
    fn take_deadline_in_past_times_out() {
        let slot: Arc<JobSlot<u32>> = JobSlot::new();
        let deadline = Instant::now().checked_add(Duration::ZERO);
        assert_eq!(slot.take(deadline), Err(TakeError::Timeout));
    }

    #[test]
    fn cap_eviction_is_oldest_first_and_skips_pending() {
        let mut store: SlotStore<u32> = SlotStore::new();
        let _pending = store.reserve(1);
        for id in 2..=4u64 {
            let s = store.reserve(id);
            store.mark_done(id);
            s.fill(id as u32);
        }
        store.evict(2, None);
        assert!(store.get(1).is_some(), "pending slot must survive eviction");
        assert!(store.get(2).is_none(), "oldest finished result evicted");
        assert!(store.get(3).is_some());
        assert!(store.get(4).is_some());
    }

    #[test]
    fn waiter_holding_slot_survives_eviction() {
        let mut store: SlotStore<u32> = SlotStore::new();
        let slot = store.reserve(1);
        store.mark_done(1);
        slot.fill(9);
        store.evict(0, None);
        assert!(store.get(1).is_none(), "store reference dropped");
        assert_eq!(slot.take(None), Ok(9), "held Arc still delivers");
    }

    #[test]
    fn forget_rolls_back_reservation() {
        let mut store: SlotStore<u32> = SlotStore::new();
        store.reserve(5);
        store.forget(5);
        assert!(store.is_empty());
    }

    #[test]
    fn done_deque_compacts_consumed_entries() {
        let mut store: SlotStore<u32> = SlotStore::new();
        for id in 0..100u64 {
            let s = store.reserve(id);
            store.mark_done(id);
            s.fill(0);
            s.try_take();
            store.consumed(id);
            store.evict(1024, None);
        }
        assert_eq!(store.len(), 0);
        assert!(store.done.len() <= 16, "stale bookkeeping kept: {}", store.done.len());
    }
}
