//! Hot-shard stream migration and the elastic controller.
//!
//! The NATSA paper's premise is placing compute next to the data it
//! scans; the software analogue in this service is making sure no one
//! shard becomes the memory channel everyone queues behind.  This
//! module supplies the two mechanisms and the policy loop:
//!
//! * [`run_migration`] — move one stream to another shard with **exact**
//!   state fidelity (profiles are bit-identical across the hop) and a
//!   crash-safe durability hand-off;
//! * [`controller_loop`] — the background policy thread: autoscaling
//!   worker pools per shard (queue-backlog signal, hysteresis) and
//!   hot→cold stream migration (sustained imbalance signal, cooldown),
//!   both configured by [`ElasticConfig`].
//!
//! # The migration protocol
//!
//! ```text
//!   source shard                                   target shard
//!   ------------                                   ------------
//!   lock submit_seq (no new appends admitted)
//!   lock state; wait next_seq == submit_seq    ← quiesce: every admitted
//!                                                append has applied
//!   capture session.state()  — the same bytes a WAL snapshot carries
//!   issue new placement epoch
//!   unlock state (submit_seq stays held)
//!                                                log Open(epoch')
//!                                                log Snapshot(epoch')
//!                                                fsync
//!   re-lock state; re-check not closed
//!   insert target entry into target streams map
//!   router.flip(placement → {target, epoch'})  ← the commit point
//!   mark source entry moved + gone
//!   log Close on source WAL
//!   unlock state, unlock submit_seq
//!   remove source map entry; wake waiters
//! ```
//!
//! Durability composes across a crash at ANY point: the target's
//! `Open`+`Snapshot` are synced **before** the source's `Close` is
//! written, so the worst case (crash in between) leaves the stream open
//! in *two* shard directories — and recovery keeps the incarnation with
//! the higher placement epoch and closes the other (see
//! `AnalysisService::try_start_sharded` and `wal_recovery.rs`).  A crash
//! before the target sync recovers the stream on the source, exactly as
//! if the migration never started.
//!
//! Bit-identity holds because the hand-off reuses the recovery path:
//! the captured [`SessionState`] is round-tripped through the WAL codec
//! (`encode` → `decode`) and rebuilt with [`StreamSession::from_state`]
//! — the same bytes, the same rebuild, as a crash restart.  The
//! quiesce barrier guarantees no append is in flight, so no tile
//! boundary shifts.
//!
//! # Locking
//!
//! The migration holds `entry.submit_seq` (class 20) then `entry.state`
//! (class 30), per the documented hierarchy; the router's `route_table`
//! is a leaf above all classes and is taken under `state` at the commit
//! point.  The one deliberate exception: the **target** shard's
//! `streams` map (class 10) is inserted into while the **source**
//! stream's `state` lock is held — the repo's single sanctioned
//! suppression of lint rule NL003 (`lock_order`), annotated at the
//! site (see `docs/INVARIANTS.md`); safe because no code path anywhere
//! acquires a `state` lock while holding a `streams`-map lock (the maps
//! are leaves in practice; the documented chain is only ever entered
//! map-first on a *single* shard), so no cycle can form.

use std::time::Duration;

use crate::coordinator::metrics::ServiceMetrics;
use crate::coordinator::router::{Placement, Router};
use crate::coordinator::service::{
    spawn_worker, Job, ServiceConfig, Shard, StreamEntry, StreamState,
};
use crate::coordinator::wal::StreamMeta;
use crate::mp::stampi::SessionState;
use crate::natsa::{NatsaConfig, StreamSession};
use crate::sync::atomic::{AtomicBool, Ordering};
use crate::sync::mpsc::Receiver;
use crate::sync::{lock_ok, thread, try_lock_ok, wait_ok, Arc, Condvar, Mutex};
use crate::Real;

/// Why a migration did not happen.  None of these leave any state
/// changed except [`MigrateError::Closed`] raced after the target
/// pre-logged (which is undone with a target-side `Close`).
#[derive(Debug, PartialEq, Eq)]
pub enum MigrateError {
    /// The stream id is unknown or already closed.
    UnknownStream,
    /// Source and destination are the same shard — nothing to do.
    SameShard,
    /// The destination shard index is out of range.
    InvalidShard(usize),
    /// The stream was closed while the migration was quiescing it.
    Closed,
    /// A concurrent close/quarantine/migration won the placement race.
    Raced,
    /// The captured state failed to round-trip onto the target engine.
    Restore(String),
}

impl std::fmt::Display for MigrateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MigrateError::UnknownStream => write!(f, "unknown or closed stream"),
            MigrateError::SameShard => write!(f, "stream already lives on that shard"),
            MigrateError::InvalidShard(k) => write!(f, "shard {k} out of range"),
            MigrateError::Closed => write!(f, "stream closed during migration"),
            MigrateError::Raced => write!(f, "placement changed during migration"),
            MigrateError::Restore(why) => write!(f, "state hand-off failed: {why}"),
        }
    }
}

/// Borrowed view of the service internals a migration needs (the
/// public entry point is
/// [`AnalysisService::migrate_stream`](crate::coordinator::service::AnalysisService::migrate_stream)).
pub(crate) struct MigrateCtx<'a, T: Real> {
    pub(crate) shards: &'a [Arc<Shard<T>>],
    pub(crate) router: &'a Router,
    pub(crate) aggregate: &'a ServiceMetrics,
    pub(crate) shard_configs: &'a [NatsaConfig],
}

/// Move `stream` to shard `to`.  See the module docs for the protocol;
/// on success ticks `streams_migrated` (source shard + aggregate), on a
/// failure after the source was resolved ticks `migration_failed`.
pub(crate) fn run_migration<T: Real>(
    cx: &MigrateCtx<'_, T>,
    stream: u64,
    to: usize,
) -> Result<(), MigrateError> {
    if to >= cx.shards.len() {
        return Err(MigrateError::InvalidShard(to));
    }
    // Resolve placement + live entry (same retry contract as the
    // service's resolve path).
    let (p, entry) = loop {
        let Some(p) = cx.router.lookup(stream) else {
            return Err(MigrateError::UnknownStream);
        };
        if let Some(e) = lock_ok(&cx.shards[p.shard].streams).get(&stream).cloned() {
            break (p, e);
        }
        match cx.router.lookup(stream) {
            None => return Err(MigrateError::UnknownStream),
            Some(p2) if p2 != p => continue,
            Some(_) => thread::yield_now(),
        }
    };
    if p.shard == to {
        return Err(MigrateError::SameShard);
    }
    let source = &cx.shards[p.shard];
    let target = &cx.shards[to];
    let fail = |e: MigrateError| {
        source.metrics.migration_failed.fetch_add(1, Ordering::Relaxed);
        cx.aggregate.migration_failed.fetch_add(1, Ordering::Relaxed);
        Err(e)
    };
    // Quiesce.  Holding `submit_seq` stops new appends from being
    // admitted against this entry; the condvar wait drains the ones
    // already admitted (each apply bumps `next_seq` and notifies).
    // Jobs of other streams keep flowing around us the whole time.
    let seq_guard = lock_ok(&entry.submit_seq);
    let assigned = *seq_guard;
    let mut st = lock_ok(&entry.state);
    while !st.closed && st.next_seq < assigned {
        st = wait_ok(&entry.cv, st);
    }
    if st.closed {
        return fail(MigrateError::Closed);
    }
    if st.moved || st.epoch != p.epoch {
        // Another migration committed this entry away between our
        // resolve and the locks.
        return fail(MigrateError::Raced);
    }
    // Capture the exact bytes a WAL snapshot would carry and round-trip
    // them through the codec onto the target's PU slice — the identical
    // rebuild a crash restart performs, so the profile is bit-identical
    // by construction.
    let sess_state = st.session.state();
    let mut bytes = Vec::new();
    sess_state.encode(&mut bytes);
    let decoded = match SessionState::<T>::decode(&bytes) {
        Ok(d) => d,
        Err(e) => return fail(MigrateError::Restore(e.to_string())),
    };
    let target_pus = cx.shard_configs[to].pus.max(1);
    let session = match StreamSession::from_state(decoded, target_pus) {
        Ok(s) => s,
        Err(e) => return fail(MigrateError::Restore(e.to_string())),
    };
    let epoch = cx.router.next_epoch();
    let meta = StreamMeta {
        m: sess_state.m,
        excl: Some(sess_state.excl),
        max_history: sess_state.max_history,
        epoch,
    };
    // Target-first durability: the new incarnation must be on disk
    // before the old one's Close is written, so a crash anywhere in
    // between recovers the stream at least once — and the epoch dedupe
    // at recovery makes it exactly once.  The state lock is released
    // across the fsync (submit_seq stays held, so the quiesce holds);
    // only reads and a racing close can touch the entry in the gap.
    drop(st);
    target.with_wal(cx.aggregate, |w| {
        w.log_open(stream, meta)?;
        w.log_snapshot(stream, epoch, assigned, &sess_state)?;
        w.sync()
    });
    let mut st = lock_ok(&entry.state);
    if st.closed {
        // close_stream won the gap.  Undo the target pre-log so replay
        // never resurrects the stream there.
        target.with_wal(cx.aggregate, |w| w.log_close(stream));
        return fail(MigrateError::Closed);
    }
    debug_assert!(!st.moved && st.next_seq == assigned, "quiesce barrier broken");
    // Commit.  Subscribers ride along: the mailboxes move into the
    // target entry in its constructor — never by locking two `state`
    // mutexes at once.
    let subs = std::mem::take(&mut st.subs);
    let target_entry = Arc::new(StreamEntry {
        state: Mutex::new(StreamState {
            session,
            next_seq: assigned,
            closed: false,
            moved: false,
            epoch,
            unsnapshotted: 0,
            subs,
        }),
        cv: Condvar::new(),
        submit_seq: Mutex::new(assigned),
        gone: AtomicBool::new(false),
    });
    // Cross-shard: the TARGET's streams map is taken while the SOURCE
    // stream's `state` lock is held.  Safe: no code path acquires a
    // `state` lock while holding any `streams`-map lock, so the
    // inverted pair cannot form a cycle.
    // natsa-lint: allow(lock_order)
    lock_ok(&target.streams).insert(stream, target_entry);
    let flipped = cx.router.flip(stream, p, Placement { shard: to, epoch });
    // Every flip-breaker (close, quarantine, another migration) needs
    // the state lock we hold, so the CAS cannot lose; if it ever did,
    // forcing the committed placement keeps the router consistent with
    // the target entry + WAL records that already exist.
    debug_assert!(flipped, "placement changed under the state lock");
    if !flipped {
        cx.router.install(stream, Placement { shard: to, epoch });
    }
    st.moved = true;
    entry.gone.store(true, Ordering::Release);
    source.with_wal(cx.aggregate, |w| w.log_close(stream));
    // Lock order: release `state` AND `submit_seq` before touching the
    // source streams map (class below both).
    drop(st);
    drop(seq_guard);
    lock_ok(&source.streams).remove(&stream);
    entry.cv.notify_all();
    source.metrics.streams_migrated.fetch_add(1, Ordering::Relaxed);
    cx.aggregate.streams_migrated.fetch_add(1, Ordering::Relaxed);
    Ok(())
}

/// Tuning for the elastic controller (enable with
/// [`ServiceConfig::with_elastic`]).  All signals are evaluated once
/// per `tick`; both actuators carry hysteresis so transient blips do
/// not thrash pools or bounce streams.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Worker-pool floor per shard (workers never shrink below this).
    pub min_workers: usize,
    /// Worker-pool ceiling per shard.
    pub max_workers: usize,
    /// Controller evaluation period.
    pub tick: Duration,
    /// Grow a pool when its queued-plus-running backlog per worker
    /// stays at or above this for `hysteresis_ticks` ticks.
    pub grow_backlog: u64,
    /// Shrink when backlog per worker stays at or below this.
    pub shrink_backlog: u64,
    /// Consecutive ticks a grow/shrink signal must persist.
    pub hysteresis_ticks: u32,
    /// Migration arms when `hottest > coldest * migrate_ratio +
    /// migrate_slack` (in in-flight jobs)…
    pub migrate_ratio: u64,
    /// …with an absolute slack so near-idle noise never triggers it.
    pub migrate_slack: u64,
    /// Consecutive ticks the imbalance must persist before migrating.
    pub migrate_ticks: u32,
    /// Ticks to sit out after a migration (let the signal re-form).
    pub cooldown_ticks: u32,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            min_workers: 1,
            max_workers: 8,
            tick: Duration::from_millis(10),
            grow_backlog: 4,
            shrink_backlog: 1,
            hysteresis_ticks: 3,
            migrate_ratio: 4,
            migrate_slack: 8,
            migrate_ticks: 3,
            cooldown_ticks: 10,
        }
    }
}

impl ElasticConfig {
    pub(crate) fn normalized(mut self, workers_per_shard: usize) -> Self {
        self.min_workers = self.min_workers.max(1);
        self.max_workers = self.max_workers.max(self.min_workers).max(workers_per_shard);
        self.hysteresis_ticks = self.hysteresis_ticks.max(1);
        self.migrate_ticks = self.migrate_ticks.max(1);
        self.migrate_ratio = self.migrate_ratio.max(1);
        self
    }
}

/// Owned handles the controller thread needs (clones of the service's
/// own Arcs; the service keeps the originals).
pub(crate) struct ControllerCtx<T: Real> {
    pub(crate) shards: Vec<Arc<Shard<T>>>,
    pub(crate) rxs: Vec<Arc<Mutex<Receiver<Job<T>>>>>,
    pub(crate) router: Arc<Router>,
    pub(crate) aggregate: Arc<ServiceMetrics>,
    pub(crate) shard_configs: Vec<NatsaConfig>,
    pub(crate) svc: ServiceConfig,
    pub(crate) workers: Arc<Mutex<Vec<thread::JoinHandle<()>>>>,
    pub(crate) stop: Arc<AtomicBool>,
}

/// The background policy loop: pool scaling + hot-shard migration.
/// Exits when the service's shutdown raises the stop flag.
pub(crate) fn controller_loop<T: Real>(cx: ControllerCtx<T>, cfg: ElasticConfig) {
    let n = cx.shards.len();
    let mut grow_streak = vec![0u32; n];
    let mut shrink_streak = vec![0u32; n];
    let mut hot_streak = 0u32;
    let mut cooldown = 0u32;
    while !cx.stop.load(Ordering::Acquire) {
        sleep_interruptibly(cfg.tick, &cx.stop);
        if cx.stop.load(Ordering::Acquire) {
            return;
        }
        scale_pools(&cx, &cfg, &mut grow_streak, &mut shrink_streak);
        if cooldown > 0 {
            cooldown -= 1;
            hot_streak = 0;
            continue;
        }
        let loads: Vec<u64> = cx.shards.iter().map(|s| s.metrics.in_flight()).collect();
        if let Some((hot, cold)) = sustained_imbalance(&loads, &cfg, &mut hot_streak) {
            if let Some(stream) = pick_busiest_stream(&cx.shards[hot]) {
                let mcx = MigrateCtx {
                    shards: &cx.shards,
                    router: &cx.router,
                    aggregate: &cx.aggregate,
                    shard_configs: &cx.shard_configs,
                };
                // Failures (stream closed mid-flight, races) are
                // normal under churn — counted in `migration_failed`,
                // retried naturally at the next armed tick.
                let _ = run_migration(&mcx, stream, cold);
                cooldown = cfg.cooldown_ticks;
            }
        }
    }
}

/// Sleep up to `d`, waking early when `stop` is raised (keeps shutdown
/// latency bounded by ~10 ms regardless of the configured tick).
fn sleep_interruptibly(d: Duration, stop: &AtomicBool) {
    let slice = Duration::from_millis(10);
    let mut left = d;
    while !left.is_zero() && !stop.load(Ordering::Acquire) {
        let s = left.min(slice);
        std::thread::sleep(s);
        left = left.saturating_sub(s);
    }
}

/// One pool-scaling pass: grow/shrink each shard's worker pool on a
/// sustained backlog-per-worker signal.  The controller is the single
/// writer of `pool.target`; workers only ever CAS `pool.size` down
/// when exiting (the gauge publish itself is multi-writer safe, see
/// [`ServiceMetrics::publish_gauge`]).
fn scale_pools<T: Real>(
    cx: &ControllerCtx<T>,
    cfg: &ElasticConfig,
    grow_streak: &mut [u32],
    shrink_streak: &mut [u32],
) {
    for (k, shard) in cx.shards.iter().enumerate() {
        let size = shard.pool.size.load(Ordering::Relaxed);
        let backlog = shard.metrics.in_flight();
        let target = shard.pool.target.load(Ordering::Relaxed) as usize;
        match scale_decision(
            backlog,
            size,
            target,
            cfg,
            &mut grow_streak[k],
            &mut shrink_streak[k],
        ) {
            ScaleAction::Grow => {
                shard.pool.target.store(target as u64 + 1, Ordering::Relaxed);
                shard.pool.size.fetch_add(1, Ordering::Relaxed);
                let h = spawn_worker(
                    cx.rxs[k].clone(),
                    shard.clone(),
                    cx.aggregate.clone(),
                    cx.router.clone(),
                    cx.shard_configs[k],
                    cx.svc.clone(),
                );
                lock_ok(&cx.workers).push(h);
            }
            ScaleAction::Shrink => {
                // Workers observe the lowered target and exit at their
                // next job boundary — never mid-job.
                shard.pool.target.store(target as u64 - 1, Ordering::Relaxed);
            }
            ScaleAction::Hold => {}
        }
        ServiceMetrics::publish_gauge(
            &shard.metrics.pool_workers,
            &cx.aggregate.pool_workers,
            shard.pool.size.load(Ordering::Relaxed),
        );
    }
}

/// What one scaling tick decided for one shard's pool.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ScaleAction {
    Grow,
    Shrink,
    Hold,
}

/// Pure grow/shrink/hold decision for one shard (the policy half of
/// [`scale_pools`], separated so it is deterministic under unit test):
/// backlog-per-worker crossing the grow/shrink thresholds for
/// `hysteresis_ticks` consecutive ticks moves `target` one step,
/// clamped to `min_workers..=max_workers`.
fn scale_decision(
    backlog: u64,
    size: u64,
    target: usize,
    cfg: &ElasticConfig,
    grow_streak: &mut u32,
    shrink_streak: &mut u32,
) -> ScaleAction {
    let per_worker = backlog / size.max(1);
    if per_worker >= cfg.grow_backlog {
        *grow_streak += 1;
        *shrink_streak = 0;
    } else if per_worker <= cfg.shrink_backlog {
        *shrink_streak += 1;
        *grow_streak = 0;
    } else {
        *grow_streak = 0;
        *shrink_streak = 0;
    }
    if *grow_streak >= cfg.hysteresis_ticks && target < cfg.max_workers {
        *grow_streak = 0;
        ScaleAction::Grow
    } else if *shrink_streak >= cfg.hysteresis_ticks && target > cfg.min_workers {
        *shrink_streak = 0;
        ScaleAction::Shrink
    } else {
        ScaleAction::Hold
    }
}

/// Detect a sustained hot/cold imbalance; returns `(hottest, coldest)`
/// once the signal has held for `migrate_ticks` consecutive ticks.
/// Pure over the load vector, so the trigger policy is unit-testable.
fn sustained_imbalance(
    loads: &[u64],
    cfg: &ElasticConfig,
    hot_streak: &mut u32,
) -> Option<(usize, usize)> {
    let hot = (0..loads.len()).max_by_key(|&k| loads[k])?;
    let cold = (0..loads.len()).min_by_key(|&k| loads[k])?;
    let armed = hot != cold
        && loads[hot]
            > loads[cold]
                .saturating_mul(cfg.migrate_ratio)
                .saturating_add(cfg.migrate_slack);
    if !armed {
        *hot_streak = 0;
        return None;
    }
    *hot_streak += 1;
    if *hot_streak < cfg.migrate_ticks {
        return None;
    }
    *hot_streak = 0;
    Some((hot, cold))
}

/// Pick the hot shard's busiest stream: most appends admitted but not
/// yet applied (`submit_seq - next_seq`), sampled with try-locks so the
/// controller never blocks behind the very backlog it is measuring.
/// Falls back to any stream when every lock is contended.
fn pick_busiest_stream<T: Real>(shard: &Shard<T>) -> Option<u64> {
    let entries: Vec<(u64, Arc<StreamEntry<T>>)> = lock_ok(&shard.streams)
        .iter()
        .map(|(&id, e)| (id, e.clone()))
        .collect();
    let mut best: Option<(u64, u64)> = None; // (pending, id)
    for (id, e) in &entries {
        let Some(seq) = try_lock_ok(&e.submit_seq) else { continue };
        let Some(st) = try_lock_ok(&e.state) else { continue };
        if st.closed || st.moved {
            continue;
        }
        let pending = seq.saturating_sub(st.next_seq);
        if best.map_or(true, |(p, _)| pending > p) {
            best = Some((pending, *id));
        }
    }
    best.map(|(_, id)| id).or_else(|| entries.first().map(|(id, _)| *id))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ElasticConfig {
        ElasticConfig {
            min_workers: 1,
            max_workers: 4,
            hysteresis_ticks: 3,
            grow_backlog: 4,
            shrink_backlog: 1,
            migrate_ratio: 4,
            migrate_slack: 8,
            migrate_ticks: 3,
            ..ElasticConfig::default()
        }
    }

    #[test]
    fn normalized_clamps_bounds() {
        let e = ElasticConfig {
            min_workers: 0,
            max_workers: 0,
            hysteresis_ticks: 0,
            migrate_ticks: 0,
            migrate_ratio: 0,
            ..ElasticConfig::default()
        }
        .normalized(3);
        assert_eq!(e.min_workers, 1);
        assert_eq!(e.max_workers, 3, "ceiling covers the startup pool");
        assert_eq!(e.hysteresis_ticks, 1);
        assert_eq!(e.migrate_ticks, 1);
        assert_eq!(e.migrate_ratio, 1);
    }

    #[test]
    fn grow_needs_a_sustained_signal() {
        let c = cfg();
        let (mut g, mut s) = (0u32, 0u32);
        // backlog 8 over 2 workers = 4/worker: at the grow threshold.
        assert_eq!(scale_decision(8, 2, 2, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(scale_decision(8, 2, 2, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(scale_decision(8, 2, 2, &c, &mut g, &mut s), ScaleAction::Grow);
        assert_eq!(g, 0, "streak resets after firing");
        // A single quiet tick in the middle resets the streak.
        assert_eq!(scale_decision(8, 2, 2, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(scale_decision(4, 2, 2, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(g, 0, "mid-band backlog clears the grow streak");
    }

    #[test]
    fn scaling_respects_the_bounds() {
        let c = cfg();
        let (mut g, mut s) = (0u32, 0u32);
        for _ in 0..20 {
            // At max_workers a saturated signal must keep holding.
            assert_eq!(
                scale_decision(100, 4, c.max_workers, &c, &mut g, &mut s),
                ScaleAction::Hold
            );
        }
        let (mut g, mut s) = (0u32, 0u32);
        for _ in 0..20 {
            // At min_workers an idle signal must keep holding.
            assert_eq!(
                scale_decision(0, 1, c.min_workers, &c, &mut g, &mut s),
                ScaleAction::Hold
            );
        }
    }

    #[test]
    fn shrink_fires_when_idle_persists() {
        let c = cfg();
        let (mut g, mut s) = (0u32, 0u32);
        assert_eq!(scale_decision(0, 3, 3, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(scale_decision(2, 3, 3, &c, &mut g, &mut s), ScaleAction::Hold);
        assert_eq!(scale_decision(1, 3, 3, &c, &mut g, &mut s), ScaleAction::Shrink);
        assert_eq!(s, 0, "streak resets after firing");
    }

    #[test]
    fn imbalance_trigger_needs_ratio_slack_and_persistence() {
        let c = cfg();
        let mut streak = 0u32;
        // 40 > 2*4 + 8: armed, but only fires on the 3rd consecutive tick.
        assert_eq!(sustained_imbalance(&[40, 2, 3], &c, &mut streak), None);
        assert_eq!(sustained_imbalance(&[40, 2, 3], &c, &mut streak), None);
        assert_eq!(sustained_imbalance(&[40, 2, 3], &c, &mut streak), Some((0, 1)));
        assert_eq!(streak, 0, "streak resets after firing");
        // Within slack: near-idle noise never arms the trigger.
        assert_eq!(sustained_imbalance(&[8, 0], &c, &mut streak), None);
        // A balanced tick in the middle resets the streak.
        assert_eq!(sustained_imbalance(&[40, 2], &c, &mut streak), None);
        assert_eq!(sustained_imbalance(&[10, 10], &c, &mut streak), None);
        assert_eq!(sustained_imbalance(&[40, 2], &c, &mut streak), None);
        assert_eq!(streak, 1);
        // Degenerate shapes are inert.
        assert_eq!(sustained_imbalance(&[], &c, &mut streak), None);
        assert_eq!(sustained_imbalance(&[99], &c, &mut streak), None);
    }

    #[test]
    fn migrate_error_messages_are_stable() {
        assert_eq!(MigrateError::SameShard.to_string(), "stream already lives on that shard");
        assert_eq!(MigrateError::InvalidShard(9).to_string(), "shard 9 out of range");
        assert_eq!(
            MigrateError::Restore("boom".into()).to_string(),
            "state hand-off failed: boom"
        );
    }
}
