//! Bounded snapshot-fanout mailboxes (one producer, N subscribers).
//!
//! Extracted from the service so the delivery protocol is a small,
//! generic, directly-testable unit: `rust/tests/loom_service.rs`
//! model-checks producer-vs-poll-vs-unsubscribe interleavings of
//! exactly these types, and the service instantiates them with
//! `P = MatrixProfile<T>`.
//!
//! Semantics (the module-level "snapshot fanout" section of
//! [`crate::coordinator::service`] is the user-facing contract):
//!
//! * a payload is computed **once** and delivered to every live
//!   subscriber as a shared `Arc` — [`deliver`] clones the `Arc`, not
//!   the payload;
//! * mailboxes are bounded with **evict-oldest** backpressure: a slow
//!   subscriber loses old snapshots (counted in its saturating lag
//!   counter, never stalls the producer);
//! * closing is **drain-then-closed**: already-queued payloads stay
//!   pollable after `close`, then [`SubRecv::Closed`] forever.
//!
//! Lock note: each mailbox has exactly one internal lock and never
//! takes another lock while holding it — it is a leaf of the
//! coordinator's lock hierarchy (see `docs/CONCURRENCY.md`).

use std::collections::VecDeque;

use crate::sync::{lock_ok, Arc, Mutex};

/// One subscriber's bounded snapshot mailbox.
pub struct SubBox<P> {
    state: Mutex<SubBoxState<P>>,
}

struct SubBoxState<P> {
    queue: VecDeque<Arc<P>>,
    /// Payloads evicted because the subscriber fell `cap` behind (the
    /// non-stalling backpressure: oldest dropped first).  Saturating —
    /// a subscriber abandoned for eons reports `u64::MAX`, not zero.
    dropped: u64,
    /// Unsubscribed, or the producing stream was closed/quarantined:
    /// delivery skips the box and poll reports `Closed` once drained.
    closed: bool,
}

/// What polling a mailbox found.
#[derive(Clone, Debug)]
pub enum SubRecv<P> {
    /// The oldest undelivered payload (shared, not cloned per
    /// subscriber).
    Snapshot(Arc<P>),
    /// Nothing queued right now; the subscription is live.
    Empty,
    /// The subscription is gone — unsubscribed, its stream closed or
    /// quarantined, or the id was never issued — and the mailbox is
    /// drained.
    Closed,
}

impl<P> SubBox<P> {
    pub fn new() -> Arc<Self> {
        Arc::new(SubBox {
            state: Mutex::new(SubBoxState { queue: VecDeque::new(), dropped: 0, closed: false }),
        })
    }

    /// Producer-side: enqueue a shared payload, evicting the oldest
    /// entry when the box already holds `cap`.  Returns `false` (and
    /// delivers nothing) when the box is closed — the caller drops it
    /// from its delivery list.
    pub fn push(&self, payload: &Arc<P>, cap: usize) -> bool {
        let mut b = lock_ok(&self.state);
        if b.closed {
            return false;
        }
        if b.queue.len() >= cap.max(1) {
            b.queue.pop_front();
            b.dropped = b.dropped.saturating_add(1);
        }
        b.queue.push_back(payload.clone());
        true
    }

    /// Subscriber-side: take the oldest undelivered payload (never
    /// blocks).  After `close`, queued payloads remain pollable until
    /// drained, then [`SubRecv::Closed`].
    pub fn poll(&self) -> SubRecv<P> {
        let mut b = lock_ok(&self.state);
        match b.queue.pop_front() {
            Some(p) => SubRecv::Snapshot(p),
            None if b.closed => SubRecv::Closed,
            None => SubRecv::Empty,
        }
    }

    /// Stop deliveries (unsubscribe / stream close / quarantine).
    /// Idempotent; queued payloads stay pollable.
    pub fn close(&self) {
        lock_ok(&self.state).closed = true;
    }

    /// Payloads this subscriber has lost to the bounded mailbox.
    pub fn dropped(&self) -> u64 {
        lock_ok(&self.state).dropped
    }

    /// Test/model hook: seed the lag counter (e.g. to its saturation
    /// boundary) without performing `u64::MAX` deliveries.
    pub fn set_dropped(&self, dropped: u64) {
        lock_ok(&self.state).dropped = dropped;
    }
}

/// Deliver one shared payload to every live mailbox of a stream (caller
/// holds the producing stream's state lock, so per-subscriber order ==
/// apply order).  Closed boxes are dropped from the delivery list; full
/// boxes evict their oldest payload instead of stalling the producer.
/// Returns the number of deliveries performed.
pub fn deliver<P>(subs: &mut Vec<(u64, Arc<SubBox<P>>)>, payload: &Arc<P>, cap: usize) -> u64 {
    let mut delivered = 0u64;
    subs.retain(|(_, sb)| {
        let live = sb.push(payload, cap);
        if live {
            delivered += 1;
        }
        live
    });
    delivered
}

/// Close every mailbox in a stream's delivery list and empty the list
/// (stream close / quarantine).  Already-queued payloads stay pollable
/// — the boxes stay in the shard's poll index until the client
/// unsubscribes; new deliveries stop immediately.
pub fn close_all<P>(subs: &mut Vec<(u64, Arc<SubBox<P>>)>) {
    for (_, sb) in subs.drain(..) {
        sb.close();
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn push_poll_fifo_shares_payload() {
        let sb: Arc<SubBox<u32>> = SubBox::new();
        let p1 = Arc::new(1u32);
        let p2 = Arc::new(2u32);
        assert!(sb.push(&p1, 8));
        assert!(sb.push(&p2, 8));
        match sb.poll() {
            SubRecv::Snapshot(got) => assert!(Arc::ptr_eq(&got, &p1), "shared, in order"),
            other => panic!("expected snapshot, got {other:?}"),
        }
        match sb.poll() {
            SubRecv::Snapshot(got) => assert!(Arc::ptr_eq(&got, &p2)),
            other => panic!("expected snapshot, got {other:?}"),
        }
        assert!(matches!(sb.poll(), SubRecv::Empty));
    }

    #[test]
    fn evict_oldest_counts_lag() {
        let sb: Arc<SubBox<u32>> = SubBox::new();
        for i in 0..5u32 {
            sb.push(&Arc::new(i), 2);
        }
        assert_eq!(sb.dropped(), 3);
        match sb.poll() {
            SubRecv::Snapshot(got) => assert_eq!(*got, 3, "oldest survivors first"),
            other => panic!("expected snapshot, got {other:?}"),
        }
    }

    #[test]
    fn lag_saturates_at_u64_max() {
        // The boundary the loom modeling pass surfaced: a wrap to 0
        // would read as "caught up" exactly when the subscriber is
        // infinitely behind.
        let sb: Arc<SubBox<u32>> = SubBox::new();
        sb.set_dropped(u64::MAX - 1);
        sb.push(&Arc::new(0), 1);
        sb.push(&Arc::new(1), 1);
        assert_eq!(sb.dropped(), u64::MAX);
        sb.push(&Arc::new(2), 1);
        assert_eq!(sb.dropped(), u64::MAX, "saturate, never wrap");
    }

    #[test]
    fn poll_after_close_drains_then_closed() {
        let sb: Arc<SubBox<u32>> = SubBox::new();
        sb.push(&Arc::new(7), 4);
        sb.push(&Arc::new(8), 4);
        sb.close();
        assert!(matches!(sb.poll(), SubRecv::Snapshot(_)));
        assert!(matches!(sb.poll(), SubRecv::Snapshot(_)));
        assert!(matches!(sb.poll(), SubRecv::Closed));
        assert!(matches!(sb.poll(), SubRecv::Closed), "closed is terminal");
        assert!(!sb.push(&Arc::new(9), 4), "no deliveries after close");
    }

    #[test]
    fn deliver_skips_and_prunes_closed_boxes() {
        let a: Arc<SubBox<u32>> = SubBox::new();
        let b: Arc<SubBox<u32>> = SubBox::new();
        let mut subs = vec![(1u64, a.clone()), (2u64, b.clone())];
        b.close();
        let delivered = deliver(&mut subs, &Arc::new(5), 4);
        assert_eq!(delivered, 1);
        assert_eq!(subs.len(), 1, "closed box pruned from delivery list");
        assert!(matches!(a.poll(), SubRecv::Snapshot(_)));
        assert!(matches!(b.poll(), SubRecv::Closed));
    }

    #[test]
    fn close_all_empties_list_keeps_queues_pollable() {
        let a: Arc<SubBox<u32>> = SubBox::new();
        let mut subs = vec![(1u64, a.clone())];
        a.push(&Arc::new(3), 4);
        close_all(&mut subs);
        assert!(subs.is_empty());
        assert!(matches!(a.poll(), SubRecv::Snapshot(_)));
        assert!(matches!(a.poll(), SubRecv::Closed));
    }
}
