//! Epoch-versioned stream routing table.
//!
//! Before elastic sharding, a stream's home shard was the low 8 bits
//! of its id (`service::shard_of`) — authority packed into the id,
//! placement fixed for life.  The router
//! inverts that: the id bits are only a *hint* (the placement at mint
//! time), and this table is the single authority on where a stream
//! lives right now.  Every entry carries a **placement epoch** — a
//! globally increasing version issued by [`Router::next_epoch`] — so
//! placement changes are compare-and-swap transitions: a migration
//! commits by [`Router::flip`]ing the entry from the exact placement it
//! resolved, a close commits by [`Router::remove_if`], and whichever
//! loses the race observes the epoch mismatch and retries or aborts.
//!
//! The same epochs are durable: the WAL logs them in every `Open` and
//! `Snapshot` record, so when a crash lands inside a migration's
//! two-directory window (target `Open`+`Snapshot` synced, source
//! `Close` not yet written) recovery keeps the incarnation with the
//! higher epoch and closes the other — see
//! [`migrate`](crate::coordinator::migrate) and `wal_recovery.rs`.
//!
//! Locking: the table's mutex (`route_table`) is a **leaf** in the
//! documented hierarchy (`docs/CONCURRENCY.md`) — nothing is ever
//! acquired under it, so it may be taken while holding any other
//! coordinator lock (the migration commit takes it under the source
//! stream's `state` lock).  The `tools/lint` `lock_order` rule
//! enforces this with `route_table` as the highest class.

use std::collections::HashMap;

use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::{lock_ok, Mutex};

/// Where a stream lives, and the version of that fact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    /// Home shard index.
    pub shard: usize,
    /// Epoch of this placement: strictly increasing across the
    /// stream's placements (and globally unique across all streams).
    pub epoch: u64,
}

/// The authoritative stream id → [`Placement`] map.
#[derive(Debug)]
pub struct Router {
    route_table: Mutex<HashMap<u64, Placement>>,
    /// Last epoch issued; restart seeds it above every epoch any shard
    /// WAL ever retained for a live stream (`wal::Replay::max_epoch`).
    epoch: AtomicU64,
}

impl Router {
    /// A router whose epoch allocator starts strictly above `floor`.
    pub fn new(floor: u64) -> Self {
        Router {
            route_table: Mutex::new(HashMap::new()),
            epoch: AtomicU64::new(floor),
        }
    }

    /// Issue a fresh placement epoch (strictly increasing).
    pub fn next_epoch(&self) -> u64 {
        self.epoch.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Install a placement verbatim (recovery path: the epoch was
    /// already issued in a previous life and replayed from the WAL).
    pub fn install(&self, stream: u64, p: Placement) {
        lock_ok(&self.route_table).insert(stream, p);
    }

    /// Route a freshly minted stream: issue an epoch, install, return
    /// the placement.
    pub fn bind(&self, stream: u64, shard: usize) -> Placement {
        let p = Placement { shard, epoch: self.next_epoch() };
        lock_ok(&self.route_table).insert(stream, p);
        p
    }

    /// Current placement of `stream`, if it is live.
    pub fn lookup(&self, stream: u64) -> Option<Placement> {
        lock_ok(&self.route_table).get(&stream).copied()
    }

    /// Commit a migration: move `stream` from exactly `from` to `to`.
    /// Fails (and changes nothing) when the current placement is no
    /// longer `from` — the caller raced a close or another migration.
    pub fn flip(&self, stream: u64, from: Placement, to: Placement) -> bool {
        debug_assert!(to.epoch > from.epoch, "placement epochs must increase");
        let mut t = lock_ok(&self.route_table);
        match t.get_mut(&stream) {
            Some(p) if *p == from => {
                *p = to;
                true
            }
            _ => false,
        }
    }

    /// Commit a close: remove `stream`'s entry iff it still is exactly
    /// `from`.  Fails (and changes nothing) on an epoch mismatch.
    pub fn remove_if(&self, stream: u64, from: Placement) -> bool {
        let mut t = lock_ok(&self.route_table);
        match t.get(&stream) {
            Some(p) if *p == from => {
                t.remove(&stream);
                true
            }
            _ => false,
        }
    }

    /// Unconditional removal (quarantine: the stream is being retired
    /// no matter what placement it reached).
    pub fn remove(&self, stream: u64) -> Option<Placement> {
        lock_ok(&self.route_table).remove(&stream)
    }

    /// Number of live streams.
    pub fn len(&self) -> usize {
        lock_ok(&self.route_table).len()
    }

    /// True when no stream is routed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Snapshot of the whole table (diagnostics / shard load scans).
    pub fn placements(&self) -> Vec<(u64, Placement)> {
        let mut v: Vec<(u64, Placement)> =
            lock_ok(&self.route_table).iter().map(|(&s, &p)| (s, p)).collect();
        v.sort_unstable_by_key(|&(s, _)| s);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bind_lookup_and_epochs_increase() {
        let r = Router::new(0);
        let a = r.bind(10, 2);
        let b = r.bind(11, 0);
        assert_eq!(a.shard, 2);
        assert!(b.epoch > a.epoch);
        assert_eq!(r.lookup(10), Some(a));
        assert_eq!(r.lookup(99), None);
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn epoch_allocator_respects_the_floor() {
        let r = Router::new(41);
        assert_eq!(r.next_epoch(), 42);
        assert_eq!(r.next_epoch(), 43);
    }

    #[test]
    fn flip_is_a_cas_on_the_exact_placement() {
        let r = Router::new(0);
        let from = r.bind(7, 0);
        let to = Placement { shard: 3, epoch: r.next_epoch() };
        // A stale `from` (wrong epoch) must not commit.
        let stale = Placement { shard: 0, epoch: from.epoch + 99 };
        assert!(!r.flip(7, stale, Placement { shard: 1, epoch: stale.epoch + 1 }));
        assert_eq!(r.lookup(7), Some(from));
        // The exact placement commits exactly once.
        assert!(r.flip(7, from, to));
        assert!(!r.flip(7, from, to));
        assert_eq!(r.lookup(7), Some(to));
    }

    #[test]
    fn remove_if_loses_to_a_concurrent_flip() {
        let r = Router::new(0);
        let from = r.bind(5, 1);
        let to = Placement { shard: 2, epoch: r.next_epoch() };
        assert!(r.flip(5, from, to));
        // A closer that resolved the old placement must observe defeat…
        assert!(!r.remove_if(5, from));
        assert_eq!(r.lookup(5), Some(to));
        // …and succeed after re-resolving.
        assert!(r.remove_if(5, to));
        assert_eq!(r.lookup(5), None);
    }

    #[test]
    fn placements_snapshot_is_sorted_and_complete() {
        let r = Router::new(0);
        let b = r.bind(9, 1);
        let a = r.bind(3, 0);
        assert_eq!(r.placements(), vec![(3, a), (9, b)]);
        r.remove(3);
        assert_eq!(r.placements(), vec![(9, b)]);
        assert!(!r.is_empty());
    }
}
