//! Layer-3 coordinator: NATSA's host logic over the PJRT request path.
//!
//! This is where the three layers compose at run time:
//!
//! 1. the host precomputes statistics and the diagonal-pair schedule
//!    (Algorithm 2, [`crate::natsa`]),
//! 2. a fleet of worker threads — one per emulated memory channel — drains
//!    PU work lists, executing the **AOT-compiled Pallas chunk kernel**
//!    through [`crate::runtime::Runtime`] for every diagonal chunk (the
//!    DPU/DPUU/DCU/PUU pipeline runs inside the kernel; the PUU's
//!    cross-chunk profile update happens here, against PU-private
//!    profiles),
//! 3. the host min-reduces the private profiles.
//!
//! Python is never involved: the kernels were lowered at build time.
//!
//! [`service`] wraps the engine in a **sharded** multi-client job queue
//! (submit / await, backpressure, per-shard + aggregate metrics) — the
//! "thin driver" face of the paper's accelerator for embedding in a
//! larger system, scaled across engine shards the way the journal
//! extension (arXiv 2206.00938) scales NATSA across accelerator stacks.
//! Alongside batch jobs (routed least-loaded-first with spill-over) it
//! hosts long-lived streaming sessions (`submit_stream` / `append_stream`
//! / `snapshot_stream`) over the exact incremental engine in
//! [`crate::mp::stampi`]; each stream lives on one shard, so pipelined
//! appends can never head-of-line block the rest of the fleet.  Stream
//! placement is **elastic**: the epoch-versioned [`router`] is the
//! authority on where a stream lives, [`migrate`] moves hot streams
//! between shards bit-identically at runtime (and autoscale worker
//! pools), and [`admission`] adds an opt-in AIMD congestion window per
//! shard.
//!
//! Sessions can outlive the process: [`wal`] gives every shard a
//! segment write-ahead log (`Open`/`Append`/`Snapshot`/`Close` records,
//! pin-based compaction), and a service started on the same directory
//! replays each open stream back **bit-identically** — see the
//! "Durability" section of [`service`]'s module docs for the ordering
//! contract and failure policy.

pub mod admission;
pub mod fanout;
pub mod metrics;
pub mod migrate;
pub mod router;
pub mod service;
pub mod slots;
pub mod wal;

use std::path::PathBuf;

use crate::sync::mpsc::{channel, Receiver, Sender};
use crate::sync::{lock_ok, thread, Arc, Mutex, OnceLock};

use anyhow::Context;

use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::natsa::{scheduler, NatsaConfig, Order};
use crate::runtime::{ArtifactKind, Manifest, Runtime, XlaReal};
use crate::timeseries::{sliding_stats, WindowStats};

/// Per-run execution metrics of the PJRT engine.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// diag_chunk kernel invocations.
    pub chunk_calls: u64,
    /// dot_init kernel invocations (one per diagonal).
    pub dot_calls: u64,
    /// Wall-clock seconds inside PJRT execute (sum across workers).
    pub kernel_seconds: f64,
    /// End-to-end wall-clock seconds.
    pub wall_seconds: f64,
    /// Worker threads used.
    pub workers: usize,
}

/// Result of a PJRT-backed NATSA run.
#[derive(Clone, Debug)]
pub struct PjrtOutput<T> {
    pub profile: MatrixProfile<T>,
    pub work: WorkStats,
    pub metrics: EngineMetrics,
}

/// A unit of accelerator work: one group of PU work lists against a
/// shared series + statistics (Arc'd so persistent workers can own it).
struct PuJob<T> {
    t: Arc<Vec<T>>,
    st: Arc<WindowStats<T>>,
    diags: Vec<usize>,
    nw: usize,
    excl: usize,
    reply: Sender<crate::Result<(MatrixProfile<T>, WorkStats, EngineMetrics)>>,
}

/// The PJRT-backed NATSA engine: same scheduling/reduction as
/// [`crate::natsa::NatsaEngine`], but every chunk of distance computation
/// runs through the AOT Pallas kernel.
///
/// Workers are **persistent** threads, each owning one PJRT client with
/// its compiled-executable cache: artifacts compile once per worker for
/// the engine's lifetime, not once per `compute` call (perf pass — the
/// per-call recompile dominated small workloads).
pub struct PjrtEngine<T: XlaReal> {
    pub config: NatsaConfig,
    pub artifact_dir: PathBuf,
    /// Worker threads (each owns a PJRT client). Defaults to 4.
    pub workers: usize,
    pool: OnceLock<Pool<T>>,
    _marker: std::marker::PhantomData<T>,
}

struct Pool<T> {
    tx: Option<Sender<PuJob<T>>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl<T: XlaReal> Drop for PjrtEngine<T> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.get_mut() {
            pool.tx.take(); // close the queue
            for h in pool.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

impl<T: XlaReal> PjrtEngine<T> {
    pub fn new(config: NatsaConfig, artifact_dir: PathBuf) -> Self {
        PjrtEngine {
            config,
            artifact_dir,
            workers: 4,
            pool: OnceLock::new(),
            _marker: std::marker::PhantomData,
        }
    }

    pub fn with_workers(mut self, workers: usize) -> Self {
        assert!(self.pool.get().is_none(), "set workers before first compute");
        self.workers = workers.max(1);
        self
    }

    fn pool(&self) -> &Pool<T> {
        self.pool.get_or_init(|| {
            let (tx, rx) = channel::<PuJob<T>>();
            let rx = Arc::new(Mutex::new(rx));
            let mut handles = Vec::new();
            for _ in 0..self.workers {
                let rx = rx.clone();
                let dir = self.artifact_dir.clone();
                handles.push(thread::spawn(move || worker_loop::<T>(rx, dir)));
            }
            Pool { tx: Some(tx), handles }
        })
    }

    /// Window lengths the loaded artifact set supports for `T`.
    pub fn supported_windows(&self) -> crate::Result<Vec<usize>> {
        Ok(Manifest::load(&self.artifact_dir)?.chunk_windows(T::DTYPE))
    }

    /// Compute the matrix profile of `t` with window `m` on the AOT path.
    ///
    /// `m` must match a lowered kernel variant (see `make artifacts`,
    /// default {32, 64, 128, 256}); anything else is an error that lists
    /// the available windows.
    pub fn compute(&self, t: &[T], m: usize) -> crate::Result<PjrtOutput<T>> {
        let cfg = match self.config.excl {
            Some(e) => MpConfig::with_excl(m, e),
            None => MpConfig::new(m),
        };
        let nw = cfg.validate(t.len())?;
        let excl = cfg.exclusion();

        // Artifact availability check up front (clear error path).
        let manifest = Manifest::load(&self.artifact_dir)?;
        manifest
            .find(ArtifactKind::DiagChunk, T::DTYPE, m)
            .with_context(|| {
                format!(
                    "no diag_chunk artifact for dtype={} m={m}; available m: {:?}",
                    T::DTYPE,
                    manifest.chunk_windows(T::DTYPE)
                )
            })?;

        // Host precompute (Alg. 2 line 2) + scheduling (line 4).
        let st = sliding_stats(t, m);
        let mut sched = scheduler::schedule(nw, excl, self.config.pus);
        match self.config.order {
            Order::Sequential => sched.sequentialize(),
            Order::Random(seed) => sched.randomize(seed),
        }

        let start = std::time::Instant::now();
        let workers = self.workers.min(self.config.pus).max(1);
        let t_arc = Arc::new(t.to_vec());
        let st_arc = Arc::new(st);

        // One job per worker: PUs dealt round-robin across job groups so
        // every group inherits the scheduler's balance.
        let pool = self.pool();
        let tx = pool.tx.as_ref().expect("pool open");
        let (reply_tx, reply_rx) = channel();
        let mut sent = 0usize;
        for g in 0..workers {
            let diags: Vec<usize> = sched
                .per_pu
                .iter()
                .skip(g)
                .step_by(workers)
                .flatten()
                .copied()
                .collect();
            if diags.is_empty() {
                continue;
            }
            tx.send(PuJob {
                t: t_arc.clone(),
                st: st_arc.clone(),
                diags,
                nw,
                excl,
                reply: reply_tx.clone(),
            })
            .map_err(|_| anyhow::anyhow!("worker pool is gone"))?;
            sent += 1;
        }
        drop(reply_tx);

        // Host reduction (Alg. 2 line 6).
        let mut profile = MatrixProfile::new_inf(nw, m, excl);
        let mut work = WorkStats::default();
        let mut metrics = EngineMetrics {
            workers,
            ..Default::default()
        };
        for _ in 0..sent {
            let (local, w, mx) = reply_rx.recv().expect("worker vanished")?;
            profile.merge(&local);
            work.add(&w);
            metrics.chunk_calls += mx.chunk_calls;
            metrics.dot_calls += mx.dot_calls;
            metrics.kernel_seconds += mx.kernel_seconds;
        }
        metrics.wall_seconds = start.elapsed().as_secs_f64();
        Ok(PjrtOutput { profile, work, metrics })
    }
}

/// Persistent worker: owns one PJRT runtime (compiled-executable cache
/// lives as long as the engine) and drains PU jobs from the shared queue.
fn worker_loop<T: XlaReal>(rx: Arc<Mutex<Receiver<PuJob<T>>>>, dir: PathBuf) {
    let mut runtime: Option<Runtime> = None;
    loop {
        let job = match lock_ok(&rx).recv() {
            Ok(j) => j,
            Err(_) => return, // engine dropped
        };
        let result = (|| -> crate::Result<_> {
            if runtime.is_none() {
                runtime = Some(Runtime::new(&dir)?);
            }
            let rt = runtime.as_ref().unwrap();
            let m = job.st.m;
            let mut local = MatrixProfile::new_inf(job.nw, m, job.excl);
            let mut work = WorkStats::default();
            let mut mx = EngineMetrics::default();
            for &d in &job.diags {
                run_diagonal_pjrt(rt, &job.t, &job.st, d, &mut local, &mut work, &mut mx)?;
            }
            Ok((local, work, mx))
        })();
        let _ = job.reply.send(result);
    }
}

/// Execute one diagonal through the AOT kernels, chunk by chunk.
fn run_diagonal_pjrt<T: XlaReal>(
    rt: &Runtime,
    t: &[T],
    st: &WindowStats<T>,
    d: usize,
    local: &mut MatrixProfile<T>,
    work: &mut WorkStats,
    mx: &mut EngineMetrics,
) -> crate::Result<()> {
    let m = st.m;
    let nw = st.len();
    let len = nw - d;
    // Available chunk variants (ascending V).  Per chunk we pick the
    // LARGEST V that does not overshoot the remaining cells (padding is
    // pure waste in interpret mode: the kernel computes all V lanes);
    // only the final sub-V tail pays for masked lanes of the smallest
    // variant (perf pass, EXPERIMENTS.md §Perf).
    let variants: Vec<usize> = rt
        .manifest()
        .chunk_variants(T::DTYPE, m)
        .iter()
        .map(|a| a.v)
        .collect();
    anyhow::ensure!(!variants.is_empty(), "diag_chunk artifact disappeared");
    let v_max = *variants.last().unwrap();

    // DPU: first dot product of the diagonal.
    let t0 = std::time::Instant::now();
    let mut q = rt.dot_init(m, &t[..m], &t[d..d + m])?;
    mx.kernel_seconds += t0.elapsed().as_secs_f64();
    mx.dot_calls += 1;
    work.first_dots += 1;
    work.diagonals += 1;

    // Chunked walk; scratch buffers sized for the largest variant and
    // re-sliced per chunk.
    let mut ta = vec![T::zero(); v_max + m];
    let mut tb = vec![T::zero(); v_max + m];
    let mut mu_a = vec![T::zero(); v_max];
    let mut sig_a = vec![T::zero(); v_max];
    let mut mu_b = vec![T::zero(); v_max];
    let mut sig_b = vec![T::zero(); v_max];

    let mut i0 = 0usize;
    while i0 < len {
        let remaining = len - i0;
        let v = *variants
            .iter()
            .rev()
            .find(|&&vv| vv <= remaining)
            .unwrap_or(&variants[0]);
        let nvalid = v.min(remaining);
        let j0 = i0 + d;
        // ta[x] = t[i0-1+x]; ta[0] is a dummy when i0 == 0 (never read:
        // delta_0 = 0 in the kernel).
        fill_shifted(&mut ta[..v + m], t, i0 as isize - 1);
        fill_shifted(&mut tb[..v + m], t, j0 as isize - 1);
        fill_stat(&mut mu_a[..v], &st.mu, i0, nvalid);
        fill_stat(&mut sig_a[..v], &st.sig, i0, nvalid);
        fill_stat(&mut mu_b[..v], &st.mu, j0, nvalid);
        fill_stat(&mut sig_b[..v], &st.sig, j0, nvalid);

        let t0 = std::time::Instant::now();
        let out = rt.diag_chunk(
            m,
            Some(v),
            &ta[..v + m],
            &tb[..v + m],
            &mu_a[..v],
            &sig_a[..v],
            &mu_b[..v],
            &sig_b[..v],
            q,
            nvalid,
        )?;
        mx.kernel_seconds += t0.elapsed().as_secs_f64();
        mx.chunk_calls += 1;

        for (k, &dist) in out.dists.iter().take(nvalid).enumerate() {
            local.update(i0 + k, j0 + k, dist);
        }
        work.cells += nvalid as u64;
        work.updates += 2 * nvalid as u64;
        // q_last is the dot product AT the chunk's last valid cell
        // (iL, jL); the next chunk's cell 0 is one Eq. 2 step further,
        // so the host advances it (2 mul + 2 add, negligible).
        let i_last = i0 + nvalid - 1;
        let j_last = i_last + d;
        i0 += nvalid;
        if i0 < len {
            q = out.q_last - t[i_last] * t[j_last] + t[i_last + m] * t[j_last + m];
        }
    }
    Ok(())
}

/// Fill `dst` with `t[start + k]`, zero outside bounds.
fn fill_shifted<T: XlaReal>(dst: &mut [T], t: &[T], start: isize) {
    for (k, slot) in dst.iter_mut().enumerate() {
        let idx = start + k as isize;
        *slot = if idx >= 0 && (idx as usize) < t.len() {
            t[idx as usize]
        } else {
            T::zero()
        };
    }
}

/// Fill `dst[0..n]` from `src[at..at+n]`, zero-pad the tail.
fn fill_stat<T: XlaReal>(dst: &mut [T], src: &[T], at: usize, n: usize) {
    for (k, slot) in dst.iter_mut().enumerate() {
        *slot = if k < n { src[at + k] } else { T::zero() };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_shifted_pads_out_of_range() {
        let t = [1.0f64, 2.0, 3.0];
        let mut dst = [9.0f64; 5];
        fill_shifted(&mut dst, &t, -1);
        assert_eq!(dst, [0.0, 1.0, 2.0, 3.0, 0.0]);
        fill_shifted(&mut dst, &t, 2);
        assert_eq!(dst, [3.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn fill_stat_pads_tail() {
        let src = [1.0f32, 2.0, 3.0, 4.0];
        let mut dst = [9.0f32; 4];
        fill_stat(&mut dst, &src, 1, 2);
        assert_eq!(dst, [2.0, 3.0, 0.0, 0.0]);
    }

    // Full PJRT integration tests live in rust/tests/e2e_pjrt.rs (they
    // need `make artifacts` to have run).
}
