//! AIMD admission control: a per-shard congestion window over
//! in-flight work.
//!
//! Borrowed from TCP congestion control by way of vector's
//! adaptive-concurrency idea (see ROADMAP): each shard carries a
//! **congestion window** `cwnd` — the number of jobs it is willing to
//! have in flight.  Every finished job under the latency target grows
//! the window additively (`+1/cwnd` per ack, so one full window of
//! acks adds one job); a latency breach or a queue-full shrinks it
//! multiplicatively (`cwnd *= decrease_pct/100`), with a cooldown so a
//! burst of breaches from the *same* congested window counts once.
//! Overload therefore degrades to fast-fail at submit time (callers
//! see `Backpressure`) with bounded queueing behind the window, rather
//! than unbounded latency pile-up; when the overload clears, additive
//! growth re-opens the window.
//!
//! The controller is **lock-free** (two atomics, CAS transitions) and
//! deterministic given a sequence of outcomes — pinned by the unit
//! tests below and the service-level test in `tests/elastic.rs`.
//! Windows are tracked in milli-jobs so additive increase needs no
//! floating point in the hot path.

use std::time::Duration;

use crate::sync::atomic::{AtomicU64, Ordering};

/// One job, in the fixed-point milli-job unit of the window.
const MILLI: u64 = 1000;

/// Tuning for the per-shard [`AimdController`].
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Window at startup, in jobs.
    pub initial_cwnd: u64,
    /// The window never shrinks below this (keeps the shard live).
    pub min_cwnd: u64,
    /// The window never grows above this.
    pub max_cwnd: u64,
    /// A job whose queue-wait + execution stays at or under this is a
    /// success (additive increase); beyond it is a breach
    /// (multiplicative decrease).
    pub latency_target: Duration,
    /// Multiplicative decrease factor in percent (50 halves the
    /// window, TCP-style).
    pub decrease_pct: u64,
    /// After a decrease, this many further outcomes are absorbed
    /// without another decrease — breaches observed by jobs that were
    /// already in flight when the window shrank carry no new signal.
    pub cooldown_acks: u64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            initial_cwnd: 32,
            min_cwnd: 1,
            max_cwnd: 4096,
            latency_target: Duration::from_millis(250),
            decrease_pct: 50,
            cooldown_acks: 16,
        }
    }
}

impl AdmissionConfig {
    fn normalized(mut self) -> Self {
        self.min_cwnd = self.min_cwnd.max(1);
        self.max_cwnd = self.max_cwnd.max(self.min_cwnd);
        self.initial_cwnd = self.initial_cwnd.clamp(self.min_cwnd, self.max_cwnd);
        self.decrease_pct = self.decrease_pct.clamp(1, 99);
        self
    }
}

/// Per-shard additive-increase / multiplicative-decrease congestion
/// window.  All state is atomic; see the module docs.
#[derive(Debug)]
pub struct AimdController {
    cfg: AdmissionConfig,
    /// Congestion window in milli-jobs.
    cwnd_milli: AtomicU64,
    /// Outcomes left to absorb before the next decrease may fire.
    cooldown: AtomicU64,
}

impl AimdController {
    pub fn new(cfg: AdmissionConfig) -> Self {
        let cfg = cfg.normalized();
        AimdController {
            cwnd_milli: AtomicU64::new(cfg.initial_cwnd * MILLI),
            cooldown: AtomicU64::new(0),
            cfg,
        }
    }

    /// Current window in milli-jobs (gauge value).
    pub fn cwnd_milli(&self) -> u64 {
        self.cwnd_milli.load(Ordering::Relaxed)
    }

    /// May a new job enter, given the shard's current in-flight count?
    /// Pure read — the caller ticks `admission_rejected` on `false`.
    pub fn try_acquire(&self, in_flight: u64) -> bool {
        in_flight.saturating_mul(MILLI) < self.cwnd_milli.load(Ordering::Relaxed)
    }

    /// Feed one finished job's total latency (queue wait + execution).
    pub fn on_outcome(&self, latency: Duration) {
        if latency <= self.cfg.latency_target {
            self.tick_cooldown();
            self.additive_increase();
        } else {
            self.multiplicative_decrease();
        }
    }

    /// The shard queue refused a job outright — hard congestion.
    pub fn on_congestion(&self) {
        self.multiplicative_decrease();
    }

    fn additive_increase(&self) {
        let max = self.cfg.max_cwnd * MILLI;
        let _ = self.cwnd_milli.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            // +1/cwnd jobs per ack: a full window of acks grows the
            // window by one job, independent of its size.
            let grown = cur + (MILLI * MILLI / cur.max(1)).max(1);
            Some(grown.min(max))
        });
    }

    fn multiplicative_decrease(&self) {
        // Absorb breaches during cooldown: jobs already in flight when
        // the window last shrank all report the same congestion event.
        let absorbed = self
            .cooldown
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| c.checked_sub(1))
            .is_ok();
        if absorbed {
            return;
        }
        let min = self.cfg.min_cwnd * MILLI;
        let _ = self.cwnd_milli.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
            Some((cur * self.cfg.decrease_pct / 100).max(min))
        });
        self.cooldown.store(self.cfg.cooldown_acks, Ordering::Relaxed);
    }

    fn tick_cooldown(&self) {
        let _ = self
            .cooldown
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| c.checked_sub(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdmissionConfig {
        AdmissionConfig {
            initial_cwnd: 8,
            min_cwnd: 1,
            max_cwnd: 64,
            latency_target: Duration::from_millis(100),
            decrease_pct: 50,
            cooldown_acks: 4,
        }
    }

    const OK: Duration = Duration::from_millis(10);
    const SLOW: Duration = Duration::from_millis(500);

    #[test]
    fn admits_strictly_under_the_window() {
        let a = AimdController::new(cfg());
        assert!(a.try_acquire(0));
        assert!(a.try_acquire(7));
        assert!(!a.try_acquire(8));
        assert!(!a.try_acquire(u64::MAX)); // saturating, no overflow
    }

    #[test]
    fn one_window_of_acks_grows_the_window_by_one_job() {
        let a = AimdController::new(cfg());
        // 8 acks at cwnd≈8: each adds 1000*1000/cwnd_milli ≈ 125 milli.
        for _ in 0..8 {
            a.on_outcome(OK);
        }
        let got = a.cwnd_milli();
        assert!(
            (8900..=9100).contains(&got),
            "expected ≈9000 milli after a full window of acks, got {got}"
        );
        assert!(a.try_acquire(8), "grown window admits one more");
    }

    #[test]
    fn a_breach_halves_the_window_once_per_cooldown() {
        let a = AimdController::new(cfg());
        a.on_outcome(SLOW);
        assert_eq!(a.cwnd_milli(), 4000, "8 → 4 on first breach");
        // The next `cooldown_acks` breaches are the same congestion
        // event: absorbed, window unchanged.
        for _ in 0..4 {
            a.on_outcome(SLOW);
        }
        assert_eq!(a.cwnd_milli(), 4000);
        // Past the cooldown a fresh breach bites again.
        a.on_outcome(SLOW);
        assert_eq!(a.cwnd_milli(), 2000);
    }

    #[test]
    fn queue_full_is_a_decrease_and_floor_holds() {
        let a = AimdController::new(cfg());
        for _ in 0..100 {
            a.on_congestion();
            // burn the cooldown deterministically
            for _ in 0..4 {
                a.on_congestion();
            }
        }
        assert_eq!(a.cwnd_milli(), 1000, "window never shrinks below min_cwnd");
        assert!(a.try_acquire(0), "min window still admits work");
        assert!(!a.try_acquire(1));
    }

    #[test]
    fn window_reopens_after_load_drops() {
        let a = AimdController::new(cfg());
        // Sustained overload collapses the window…
        for _ in 0..40 {
            a.on_outcome(SLOW);
        }
        let collapsed = a.cwnd_milli();
        assert!(collapsed < 8000, "overload must shrink the window, got {collapsed}");
        // …then healthy traffic grows it back (additive, so it takes a
        // while — that is the point).
        for _ in 0..2000 {
            a.on_outcome(OK);
        }
        assert!(a.cwnd_milli() > collapsed);
        assert!(a.cwnd_milli() >= 8000, "window recovered to its initial size");
    }

    #[test]
    fn growth_caps_at_max_cwnd() {
        let a = AimdController::new(AdmissionConfig { max_cwnd: 9, ..cfg() });
        for _ in 0..10_000 {
            a.on_outcome(OK);
        }
        assert_eq!(a.cwnd_milli(), 9000);
    }

    #[test]
    fn successes_burn_cooldown_too() {
        let a = AimdController::new(cfg());
        a.on_outcome(SLOW); // 8 → 4, cooldown = 4
        for _ in 0..4 {
            a.on_outcome(OK); // burns cooldown while growing
        }
        let before = a.cwnd_milli();
        a.on_outcome(SLOW); // cooldown spent: decrease fires
        assert!(a.cwnd_milli() < before);
    }
}
