//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! Used by every target in `benches/` (`harness = false`).  Provides
//! warmup + repeated timing with median/min/mean reporting, black-box
//! value sinking, and aligned table printing for the paper-style rows.

use std::time::Instant;

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Compile-time SIMD class, recorded in the `BENCH_*.json` trajectory
/// rows so numbers from target-cpu=native and baseline builds stay
/// distinguishable.  One shared vocabulary for every bench target —
/// `BENCH_hotpath.json` and `BENCH_streaming.json` must stay
/// comparable.
pub fn isa() -> &'static str {
    if cfg!(target_feature = "avx512f") {
        "avx512"
    } else if cfg!(target_feature = "avx2") {
        "avx2"
    } else if cfg!(target_arch = "x86_64") {
        "sse2"
    } else {
        std::env::consts::ARCH
    }
}

/// One measured statistic set (seconds).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub median: f64,
    pub min: f64,
    pub mean: f64,
    pub reps: usize,
}

impl Sample {
    pub fn throughput(&self, items: u64) -> f64 {
        items as f64 / self.median
    }
}

/// Time `f` with `warmup` + `reps` runs; returns stats over the reps.
pub fn time<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        median: times[times.len() / 2],
        min: times[0],
        mean: times.iter().sum::<f64>() / times.len() as f64,
        reps: times.len(),
    }
}

/// Adaptive: pick reps so total time ~ `budget_s`, then measure.
pub fn time_budget<F: FnMut()>(budget_s: f64, mut f: F) -> Sample {
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((budget_s / once) as usize).clamp(3, 1000);
    time(1, reps, f)
}

/// Pretty time formatting.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// A minimal aligned-table printer for bench reports.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        println!("\n== {title} ==");
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", line(&self.header));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_reports_positive() {
        let s = time(1, 5, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.median > 0.0 && s.min <= s.median && s.reps == 5);
    }

    #[test]
    fn budget_clamps_reps() {
        let s = time_budget(0.01, || {
            black_box((0..10_000).sum::<u64>());
        });
        assert!(s.reps >= 3 && s.reps <= 1000);
    }

    #[test]
    fn fmt_units() {
        assert!(fmt_time(2.0).ends_with('s'));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-9).ends_with("ns"));
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn table_checks_columns() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["x".to_string()]);
    }
}
