//! `natsa` — command-line front end for the NATSA reproduction.
//!
//! Subcommands:
//!   generate   synthesize a time series to a file
//!   profile    compute a matrix profile (scrimp/stomp/brute/natsa/pjrt)
//!   anytime    interruptible NATSA run with a work budget
//!   serve      drive the sharded analysis service with synthetic clients
//!   simulate   evaluate a platform timing/power model on a workload
//!   repro      regenerate a paper table/figure (or `all`)
//!   artifacts  list the AOT kernel artifacts the runtime can load
//!
//! Argument parsing is hand-rolled (`--key value` pairs): the offline
//! vendor set has no clap.

// Same zero-`unsafe` policy as the library crate (rust/src/lib.rs).
#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::PathBuf;

use natsa::sync::Arc;

use natsa::coordinator::admission::AdmissionConfig;
use natsa::coordinator::migrate::ElasticConfig;
use natsa::coordinator::service::{AnalysisService, ServiceConfig, SubmitError};
use natsa::coordinator::PjrtEngine;
use natsa::mp::{brute, parallel, scrimp, stomp, MpConfig};
use natsa::natsa::anytime::{run_anytime, Budget};
use natsa::natsa::{NatsaConfig, NatsaEngine, Order};
use natsa::runtime::{default_artifact_dir, Manifest};
use natsa::sim::accel::NatsaDesign;
use natsa::sim::platform::GpPlatform;
use natsa::sim::{Precision, Workload};
use natsa::timeseries::generator::{self, Pattern};
use natsa::timeseries::io;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs after the subcommand.
struct Opts(HashMap<String, String>);

impl Opts {
    fn parse(args: &[String]) -> anyhow::Result<Opts> {
        let mut map = HashMap::new();
        let mut it = args.iter();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --flag, got '{a}'"))?;
            let val = it
                .next()
                .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?;
            map.insert(key.to_string(), val.clone());
        }
        Ok(Opts(map))
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.0.get(key).map(|s| s.as_str())
    }

    fn usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }
}

fn series_from(opts: &Opts) -> anyhow::Result<Vec<f64>> {
    if let Some(path) = opts.get("input") {
        return io::load_series(&PathBuf::from(path));
    }
    let pattern = Pattern::parse(opts.get("pattern").unwrap_or("random-walk"))
        .ok_or_else(|| anyhow::anyhow!("unknown pattern (see `generate`)"))?;
    let n = opts.usize("n", 16_384)?;
    let seed = opts.u64("seed", 42)?;
    Ok(generator::generate(pattern, n, seed))
}

fn dispatch(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let opts = Opts::parse(&args[1..])?;
    match cmd.as_str() {
        "generate" => cmd_generate(&opts),
        "profile" => cmd_profile(&opts),
        "anytime" => cmd_anytime(&opts),
        "serve" => cmd_serve(&opts),
        "simulate" => cmd_simulate(&opts),
        "repro" => cmd_repro(&opts),
        "artifacts" => cmd_artifacts(&opts),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}' (try `natsa help`)"),
    }
}

fn print_usage() {
    println!(
        "natsa — NATSA (ICCD 2020) reproduction\n\n\
         usage: natsa <command> [--key value ...]\n\n\
         commands:\n\
         \x20 generate  --pattern <random-walk|sine|ecg|seismic|motif> --n N --seed S --out FILE\n\
         \x20 profile   --engine <scrimp|stomp|brute|natsa|parallel|pjrt> --m M\n\
         \x20           [--input FILE | --pattern P --n N --seed S] [--out FILE]\n\
         \x20           [--pus 48] [--threads T] [--precision f32|f64] [--order seq|random]\n\
         \x20 anytime   --fraction F --m M [--pattern P --n N]\n\
         \x20 serve     [--shards 4] [--workers 2] [--depth 16] [--pus 48] [--m 64]\n\
         \x20           [--streams 6] [--packets 24] [--chunk 512] [--jobs 12]\n\
         \x20           [--wal-dir DIR]  (durable per-shard WAL; recovers open streams on restart)\n\
         \x20           [--elastic on [--max-workers N]] [--admission on]  (elastic sharding / AIMD)\n\
         \x20 simulate  --platform <ddr4-ooo|ddr4-inorder|hbm-ooo|hbm-inorder|natsa|natsa-ddr4>\n\
         \x20           --n N --m M [--precision dp|sp]\n\
         \x20 repro     --id <fig1|fig3|fig4|fig7|table2|fig8|fig9|fig10|table3|fig11|fig12|sens-m|all>\n\
         \x20 artifacts [--dir artifacts]"
    );
}

fn cmd_generate(opts: &Opts) -> anyhow::Result<()> {
    let t = series_from(opts)?;
    match opts.get("out") {
        Some(path) => {
            io::save_series(&PathBuf::from(path), &t)?;
            println!("wrote {} points to {path}", t.len());
        }
        None => {
            for v in &t {
                println!("{v}");
            }
        }
    }
    Ok(())
}

fn cmd_profile(opts: &Opts) -> anyhow::Result<()> {
    let t = series_from(opts)?;
    let m = opts.usize("m", 128)?;
    let engine = opts.get("engine").unwrap_or("natsa");
    let threads = opts.usize("threads", 0)?;
    let pus = opts.usize("pus", 48)?;
    let order = match opts.get("order") {
        Some("random") => Order::Random(opts.u64("seed", 42)?),
        _ => Order::Sequential,
    };
    let cfg = MpConfig::new(m);
    let start = std::time::Instant::now();

    let (p, i): (Vec<f64>, Vec<i64>) = match engine {
        "scrimp" => {
            let mp = scrimp::matrix_profile(&t, cfg)?;
            (mp.p, mp.i)
        }
        "stomp" => {
            let mp = stomp::matrix_profile(&t, cfg)?;
            (mp.p, mp.i)
        }
        "brute" => {
            let mp = brute::matrix_profile(&t, cfg)?;
            (mp.p, mp.i)
        }
        "parallel" => {
            let thr = if threads == 0 { 8 } else { threads };
            let mp = parallel::matrix_profile(&t, cfg, thr)?;
            (mp.p, mp.i)
        }
        "natsa" => {
            let mut config = NatsaConfig::default().with_pus(pus).with_order(order);
            if threads > 0 {
                config = config.with_threads(threads);
            }
            let out = NatsaEngine::new(config).compute(&t, m)?;
            println!(
                "natsa: {} PUs, imbalance {:.3}, {} cells",
                pus, out.schedule_imbalance, out.work.cells
            );
            (out.profile.p, out.profile.i)
        }
        "pjrt" => {
            if opts.get("precision") == Some("f32") {
                let t32: Vec<f32> = t.iter().map(|&x| x as f32).collect();
                let engine = PjrtEngine::<f32>::new(
                    NatsaConfig::default().with_pus(pus).with_order(order),
                    default_artifact_dir(),
                )
                .with_workers(if threads == 0 { 4 } else { threads });
                let out = engine.compute(&t32, m)?;
                println!(
                    "pjrt: {} chunk calls, {} dot calls, kernel {:.2}s, wall {:.2}s",
                    out.metrics.chunk_calls,
                    out.metrics.dot_calls,
                    out.metrics.kernel_seconds,
                    out.metrics.wall_seconds
                );
                (
                    out.profile.p.iter().map(|&x| x as f64).collect(),
                    out.profile.i,
                )
            } else {
                let engine = PjrtEngine::<f64>::new(
                    NatsaConfig::default().with_pus(pus).with_order(order),
                    default_artifact_dir(),
                )
                .with_workers(if threads == 0 { 4 } else { threads });
                let out = engine.compute(&t, m)?;
                println!(
                    "pjrt: {} chunk calls, {} dot calls, kernel {:.2}s, wall {:.2}s",
                    out.metrics.chunk_calls,
                    out.metrics.dot_calls,
                    out.metrics.kernel_seconds,
                    out.metrics.wall_seconds
                );
                (out.profile.p, out.profile.i)
            }
        }
        other => anyhow::bail!("unknown engine '{other}'"),
    };
    let dt = start.elapsed().as_secs_f64();

    let (motif_i, motif_d) = p
        .iter()
        .enumerate()
        .filter(|(_, d)| d.is_finite())
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(k, d)| (k, *d))
        .unwrap_or((0, f64::NAN));
    let (disc_i, disc_d) = p
        .iter()
        .enumerate()
        .filter(|(_, d)| d.is_finite())
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(k, d)| (k, *d))
        .unwrap_or((0, f64::NAN));
    println!(
        "{engine}: n={}, m={m}, {:.3}s | motif @{motif_i} d={motif_d:.4} | discord @{disc_i} d={disc_d:.4}",
        t.len(),
        dt
    );
    if let Some(path) = opts.get("out") {
        io::save_profile(&PathBuf::from(path), &p, &i)?;
        println!("profile written to {path}");
    }
    Ok(())
}

fn cmd_anytime(opts: &Opts) -> anyhow::Result<()> {
    let t = series_from(opts)?;
    let m = opts.usize("m", 128)?;
    let fraction: f64 = opts.get("fraction").unwrap_or("0.2").parse()?;
    let config = NatsaConfig::default().with_order(Order::Random(opts.u64("seed", 42)?));
    let out = run_anytime(&t, m, &config, Budget::Fraction(fraction))?;
    let (mi, md) = out.profile.motif().unwrap();
    println!(
        "anytime: {:.1}% of cells, {} diagonals | best motif so far @{mi} d={md:.4}",
        out.progress * 100.0,
        out.diagonals_done
    );
    Ok(())
}

/// Drive the sharded analysis service with synthetic stream + batch
/// clients — the CLI face of the multi-stream deployment: streams pin to
/// their shard, batch jobs flow least-loaded-first around them, and the
/// per-shard metrics must reconcile with the aggregate at the end.
fn cmd_serve(opts: &Opts) -> anyhow::Result<()> {
    let shards = opts.usize("shards", 4)?;
    let workers = opts.usize("workers", 2)?;
    let depth = opts.usize("depth", 16)?;
    let pus = opts.usize("pus", 48)?;
    let m = opts.usize("m", 64)?;
    let streams = opts.usize("streams", 6)?;
    let packets = opts.usize("packets", 24)?;
    let chunk = opts.usize("chunk", 512)?;
    let jobs = opts.usize("jobs", 12)?;
    let wal_dir = opts.get("wal-dir").map(PathBuf::from);
    let elastic = opts.get("elastic").map(|v| v == "on" || v == "true").unwrap_or(false);
    let admission = opts.get("admission").map(|v| v == "on" || v == "true").unwrap_or(false);

    println!(
        "serve: {shards} shards x {workers} workers (depth {depth}), {pus} PUs total; \
         {streams} streams x {packets} packets x {chunk} samples + {jobs} batch jobs"
    );
    let mut svc_config = ServiceConfig::default()
        .with_shards(shards)
        .with_workers(workers)
        .with_queue_depth(depth);
    if let Some(dir) = wal_dir {
        println!("wal: per-shard durable log under {}", dir.display());
        svc_config = svc_config.with_wal(dir);
    }
    if elastic {
        let max = opts.usize("max-workers", workers.max(1) * 4)?;
        println!("elastic: autoscaling pools up to {max} workers/shard + hot-stream migration");
        svc_config = svc_config.with_elastic(ElasticConfig {
            max_workers: max,
            ..ElasticConfig::default()
        });
    }
    if admission {
        println!("admission: AIMD congestion window per shard");
        svc_config = svc_config.with_admission(AdmissionConfig::default());
    }
    // try_start_sharded, not start_sharded: a damaged WAL directory
    // should surface as a CLI error, not a panic.
    let service: Arc<AnalysisService<f64>> = Arc::new(AnalysisService::try_start_sharded(
        NatsaConfig::default().with_pus(pus),
        svc_config,
    )?);

    let mut clients = Vec::new();
    for c in 0..streams {
        let svc = service.clone();
        clients.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let t = generator::generate::<f64>(Pattern::EcgLike, packets * chunk, c as u64);
            let stream = svc.submit_stream(m, None).map_err(|e| anyhow::anyhow!("{e}"))?;
            let mut pending = std::collections::VecDeque::new();
            for packet in t.chunks(chunk) {
                // pipelined feeding: on backpressure the service-side
                // loop consumes the oldest ack and retries the packet
                let (_, drained) = svc
                    .append_stream_pipelined(stream, packet, &mut pending)
                    .map_err(|e| anyhow::anyhow!("append: {e}"))?;
                for r in drained {
                    r.profile.map_err(anyhow::Error::msg)?;
                }
            }
            for id in pending {
                svc.wait(id)
                    .map_err(|e| anyhow::anyhow!("{e}"))?
                    .profile
                    .map_err(anyhow::Error::msg)?;
            }
            anyhow::ensure!(svc.close_stream(stream), "stream vanished");
            Ok(())
        }));
    }
    for c in 0..jobs {
        let svc = service.clone();
        clients.push(std::thread::spawn(move || -> anyhow::Result<()> {
            let n = 2048 + 512 * (c % 4);
            let series = Arc::new(generator::generate::<f64>(
                Pattern::SeismicLike,
                n,
                1000 + c as u64,
            ));
            let id = loop {
                match svc.submit(series.clone(), m) {
                    Ok(id) => break id,
                    Err(SubmitError::Backpressure) => {
                        std::thread::sleep(std::time::Duration::from_millis(1))
                    }
                    Err(e) => anyhow::bail!("submit: {e}"),
                }
            };
            svc.wait(id)
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .profile
                .map_err(anyhow::Error::msg)?;
            Ok(())
        }));
    }
    for c in clients {
        c.join().expect("client panicked")?;
    }

    for k in 0..service.num_shards() {
        println!("shard {k}: {}", service.shard_metrics(k).summary());
    }
    println!("aggregate: {}", service.metrics().summary());
    anyhow::ensure!(service.metrics().in_flight() == 0, "jobs left in flight");
    anyhow::ensure!(
        service.retained_results() == 0,
        "results leaked past their consumers"
    );
    Ok(())
}

fn cmd_simulate(opts: &Opts) -> anyhow::Result<()> {
    let n = opts.usize("n", 524_288)?;
    let m = opts.usize("m", 256)?;
    let prec = match opts.get("precision").unwrap_or("dp") {
        "sp" | "f32" => Precision::Sp,
        _ => Precision::Dp,
    };
    let w = Workload::new(n, m);
    let e = match opts.get("platform").unwrap_or("natsa") {
        "ddr4-ooo" => GpPlatform::ddr4_ooo().estimate(&w, prec),
        "ddr4-inorder" => GpPlatform::ddr4_inorder().estimate(&w, prec),
        "hbm-ooo" => GpPlatform::hbm_ooo().estimate(&w, prec),
        "hbm-inorder" => GpPlatform::hbm_inorder().estimate(&w, prec),
        "natsa" => NatsaDesign::hbm(prec).estimate(&w),
        "natsa-ddr4" => NatsaDesign::ddr4(prec).estimate(&w),
        other => anyhow::bail!("unknown platform '{other}'"),
    };
    println!(
        "{} [{}] n={n} m={m}: {:.2}s, {:.1} GB/s, {:.1} W, {:.0} J ({}-bound)",
        e.platform,
        e.precision.name(),
        e.time_s,
        e.bw_gbs,
        e.power_w,
        e.energy_j,
        e.bound
    );
    Ok(())
}

fn cmd_repro(opts: &Opts) -> anyhow::Result<()> {
    let id = opts.get("id").unwrap_or("all");
    if id == "all" {
        for id in natsa::report::ALL {
            println!("{}", natsa::report::run(id)?);
        }
    } else {
        println!("{}", natsa::report::run(id)?);
    }
    Ok(())
}

fn cmd_artifacts(opts: &Opts) -> anyhow::Result<()> {
    let dir = PathBuf::from(opts.get("dir").unwrap_or("artifacts"));
    let manifest = Manifest::load(&dir)?;
    println!("{} artifacts in {}:", manifest.artifacts.len(), dir.display());
    for a in &manifest.artifacts {
        println!(
            "  {:28} kind={:?} dtype={} m={} v={} n={}",
            a.name, a.kind, a.dtype, a.m, a.v, a.n
        );
    }
    Ok(())
}
