//! NATSA: the accelerator's host API and functional engine.
//!
//! This module is Algorithm 2 of the paper:
//!
//! ```text
//! function P, I <- NATSA(T, m, exc, conf)
//!     mu, sig <- precalculateMeanDev(T, m)          // host CPU
//!     PP, II  <- allocatePrivateProfiles(T, m, exc) // per-PU vectors
//!     idx     <- diagonalScheduling(T, m, exc)      // Section 4.2
//!     START_ACCELERATOR(T, m, exc, conf, idx, PP, II)
//!     P, I    <- reduction(PP, II)                  // host CPU
//! ```
//!
//! [`NatsaEngine`] executes the accelerator step with host threads standing
//! in for the 48 PUs (each PU's work list and private profile is preserved
//! 1:1, so schedules, load accounting and anytime behaviour are faithful;
//! only the physical substrate differs).  The `diagonalScheduling` step is
//! **band-granular** ([`scheduler::schedule_banded`]): PUs are dealt
//! balanced pairs of adjacent-diagonal *tiles*, so every PU executes the
//! kernel's multi-lane band path ([`crate::mp::kernel::compute_band_n`])
//! instead of walking one diagonal at a time — same cells, bit-identical
//! values, ~2x fewer instructions per cell.  The PJRT-backed engine that
//! runs the *AOT Pallas kernels* per chunk lives in [`crate::coordinator`]
//! and reuses the classic per-diagonal scheduling (its lowered kernel
//! artifacts consume single diagonals) plus this module's reduction.

pub mod anytime;
pub mod pu;
pub mod scheduler;

use crate::mp::kernel::compute_band_n;
use crate::mp::stampi::{Stampi, StampiConfig};
use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::timeseries::sliding_stats;
use crate::Real;
use scheduler::BandedSchedule;

/// Diagonal visiting order within each PU (Section 4.2, ways 1 and 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// Sequential: locality-friendly, forfeits the anytime property.
    Sequential,
    /// Random (seeded): preserves the anytime property.
    Random(u64),
}

/// Accelerator configuration (`conf` of Algorithm 2).
#[derive(Clone, Copy, Debug)]
pub struct NatsaConfig {
    /// Number of processing units (48 in the paper's HBM design).
    pub pus: usize,
    /// Host threads emulating the PU fleet (defaults to available
    /// parallelism; PU→thread mapping is round-robin).
    pub threads: Option<usize>,
    /// Diagonal order within each PU.
    pub order: Order,
    /// Exclusion-zone radius override (`exc`); `None` = m/4.
    pub excl: Option<usize>,
}

impl Default for NatsaConfig {
    fn default() -> Self {
        NatsaConfig {
            pus: 48,
            threads: None,
            order: Order::Sequential,
            excl: None,
        }
    }
}

impl NatsaConfig {
    pub fn with_pus(mut self, pus: usize) -> Self {
        self.pus = pus;
        self
    }

    pub fn with_order(mut self, order: Order) -> Self {
        self.order = order;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    fn host_threads(&self) -> usize {
        self.threads.unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        })
    }

    /// Shard `k`'s slice of this configuration: the PU fleet (and any
    /// explicit host-thread budget) divided across `shards` slices with
    /// the remainder dealt to the first shards, so the slices sum back to
    /// the whole fleet (48 PUs over 5 shards = 10+10+10+9+9, never 45).
    /// The sharded analysis service uses this so N shards together still
    /// model the paper's single fleet, the same way the journal extension
    /// (arXiv 2206.00938) splits work across accelerator stacks.  Each
    /// slice keeps at least one PU/thread, so with more shards than PUs
    /// the slices oversubscribe rather than starve.
    pub fn shard_slice(mut self, shards: usize, k: usize) -> Self {
        let shards = shards.max(1);
        let k = k % shards;
        let split = |total: usize| (total / shards + usize::from(k < total % shards)).max(1);
        self.pus = split(self.pus);
        if let Some(t) = self.threads {
            self.threads = Some(split(t));
        }
        self
    }
}

/// Result of a NATSA run.
#[derive(Clone, Debug)]
pub struct NatsaOutput<T> {
    /// The reduced profile `P`, `I`.
    pub profile: MatrixProfile<T>,
    /// Aggregate functional work (drives the timing models).
    pub work: WorkStats,
    /// Cells executed by each PU (load-balance evidence).
    pub pu_cells: Vec<u64>,
    /// The schedule that was executed.
    pub schedule_imbalance: f64,
}

/// The functional NATSA engine (native execution substrate).
pub struct NatsaEngine<T> {
    pub config: NatsaConfig,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Real> NatsaEngine<T> {
    pub fn new(config: NatsaConfig) -> Self {
        NatsaEngine { config, _marker: std::marker::PhantomData }
    }

    /// Algorithm 2: compute the full matrix profile of `t` with window `m`.
    pub fn compute(&self, t: &[T], m: usize) -> crate::Result<NatsaOutput<T>> {
        let cfg = match self.config.excl {
            Some(e) => MpConfig::with_excl(m, e),
            None => MpConfig::new(m),
        };
        let nw = cfg.validate(t.len())?;
        let excl = cfg.exclusion();

        // Host: statistics precompute + band-granular diagonal
        // scheduling (tiles of adjacent diagonals, so every PU rides the
        // kernel's multi-lane band path — see [`scheduler`]).
        let st = sliding_stats(t, m);
        let mut sched = scheduler::schedule_banded(nw, excl, self.config.pus);
        match self.config.order {
            Order::Sequential => sched.sequentialize(),
            Order::Random(seed) => sched.randomize(seed),
        }
        let imbalance = sched.imbalance();

        // Accelerator: PUs execute their work lists with private profiles.
        let (locals, pu_cells) = run_pus(t, &st, &sched, excl, self.config.host_threads());

        // Host: reduction of the private profiles.
        let mut profile = MatrixProfile::new_inf(nw, m, excl);
        let mut work = WorkStats::default();
        for (local, w) in &locals {
            profile.merge(local);
            work.add(w);
        }
        profile.sqrt_in_place(); // diagonals accumulate squared distances
        Ok(NatsaOutput { profile, work, pu_cells, schedule_imbalance: imbalance })
    }

    /// Open a continuous-monitoring session on this engine: an exact
    /// matrix profile maintained under `append(sample)` with unbounded
    /// history (see [`crate::mp::stampi`] for the algorithm).
    pub fn open_stream(&self, m: usize) -> crate::Result<StreamSession<T>> {
        self.open_stream_bounded(m, None)
    }

    /// Like [`Self::open_stream`], retaining only the last `max_history`
    /// samples when a bound is given (O(history) memory on an unbounded
    /// stream; see the bounded-history semantics in [`crate::mp::stampi`]).
    pub fn open_stream_bounded(
        &self,
        m: usize,
        max_history: Option<usize>,
    ) -> crate::Result<StreamSession<T>> {
        let mut cfg = StampiConfig::new(m);
        if let Some(e) = self.config.excl {
            cfg = cfg.with_excl(e);
        }
        if let Some(h) = max_history {
            cfg = cfg.with_max_history(h);
        }
        let pus = self.config.pus.max(1);
        Ok(StreamSession {
            core: Stampi::new(cfg)?,
            pu_cells: vec![0; pus],
            rr: 0,
        })
    }
}

/// A streaming analysis session bound to a PU fleet.
///
/// Each appended sample produces one incremental row of distance-matrix
/// cells — executed through the unified row kernel
/// ([`crate::mp::kernel::compute_row_n`]): width-1 tiles under
/// [`Self::append`], multi-row tiles under [`Self::extend`].  The session
/// deals the evaluated cells to the PUs round-robin (whole-share split
/// plus a rotating remainder cursor — per row when appending, per batch
/// when extending), the streaming analogue of the diagonal-pair scheme:
/// every PU's cell count stays within one cell of every other's across
/// the whole stream.  The attribution is
/// *accounting* — rows are far too short to be worth host-thread fan-out,
/// so execution is in-line — but it gives the timing/energy plane
/// ([`crate::sim`]) the same per-PU [`WorkStats`] evidence the batch
/// engine emits, so streaming workloads can be costed on the paper's
/// platform models.
pub struct StreamSession<T> {
    core: Stampi<T>,
    pu_cells: Vec<u64>,
    /// Round-robin cursor for remainder cells (keeps loads within 1).
    rr: usize,
}

impl<T: Real> StreamSession<T> {
    /// Append one sample; returns the completed window's absolute index
    /// once the stream is at least `m` samples long.
    pub fn append(&mut self, x: T) -> Option<usize> {
        let out = self.core.append(x)?;
        if out.row_cells > 0 {
            self.rr = stride_deal(self.rr, out.row_cells, &mut self.pu_cells);
        }
        Some(out.window)
    }

    /// Append a batch; returns how many windows were completed.
    ///
    /// Batches ride [`Stampi::extend`]'s blocked fast path: up to
    /// `kernel::BAND` buffered samples advance as one multi-row tile of
    /// the unified row kernel, so batched feeding (the service's
    /// `append_stream` jobs) amortizes lane fill exactly like the batch
    /// fleet.  The evaluated cells are dealt to the PUs once per batch —
    /// cumulative loads still stay within one cell of each other.
    pub fn extend(&mut self, xs: &[T]) -> usize {
        let before = self.core.work().cells;
        let completed = self.core.extend(xs);
        let cells = self.core.work().cells - before;
        if cells > 0 {
            self.rr = stride_deal(self.rr, cells, &mut self.pu_cells);
        }
        completed
    }

    /// Snapshot the live profile (see [`Stampi::profile`] for indexing).
    pub fn profile(&self) -> MatrixProfile<T> {
        self.core.profile()
    }

    /// Total samples appended.
    pub fn len(&self) -> usize {
        self.core.len()
    }

    pub fn is_empty(&self) -> bool {
        self.core.is_empty()
    }

    /// Absolute index of the oldest retained window (0 when unbounded).
    pub fn first_window(&self) -> usize {
        self.core.first_window()
    }

    /// Window length `m` (the cross-stream coalescing group key, with
    /// [`Self::exclusion`] and the dtype).
    pub fn m(&self) -> usize {
        self.core.m()
    }

    /// Exclusion-zone half-width.
    pub fn exclusion(&self) -> usize {
        self.core.exclusion()
    }

    /// Aggregate functional work so far (drives the timing models).
    pub fn work(&self) -> WorkStats {
        self.core.work()
    }

    /// Cells attributed to each PU (load-balance evidence, like
    /// [`NatsaOutput::pu_cells`]).
    pub fn pu_cells(&self) -> &[u64] {
        &self.pu_cells
    }

    /// max/min load ratio over the PUs that received cells so far (1.0 =
    /// perfectly balanced).  PUs still idle — the stream is young, or
    /// shorter than one exclusion zone — are excluded, like
    /// [`scheduler::Schedule::imbalance`]; their count is
    /// [`Self::idle_pus`].
    pub fn imbalance(&self) -> f64 {
        let mut max = 0u64;
        let mut min = u64::MAX;
        for &c in &self.pu_cells {
            if c > 0 {
                max = max.max(c);
                min = min.min(c);
            }
        }
        if max == 0 {
            1.0
        } else {
            max as f64 / min as f64
        }
    }

    /// PUs that have not been dealt any cells yet.
    pub fn idle_pus(&self) -> usize {
        self.pu_cells.iter().filter(|&&c| c == 0).count()
    }

    /// Extract the session's canonical serializable state (see
    /// [`crate::mp::stampi::SessionState`]) — the compact currency the
    /// per-shard WAL snapshots and a future shard migration hands off.
    pub fn state(&self) -> crate::mp::stampi::SessionState<T> {
        self.core.state()
    }

    /// Rebuild a session from its canonical state on a `pus`-wide fleet.
    ///
    /// The engine core (profile, q chains, rolling sums, work totals) is
    /// restored **bit-identically**; the per-PU cell *attribution* is
    /// re-dealt from the restored cumulative total in one pass, which
    /// lands every PU within one cell of the incremental dealing — the
    /// same balance bound the live path guarantees, so the timing/energy
    /// evidence stays valid across a restore.
    pub fn from_state(
        state: crate::mp::stampi::SessionState<T>,
        pus: usize,
    ) -> crate::Result<Self> {
        let core = Stampi::from_state(state)?;
        let mut pu_cells = vec![0; pus.max(1)];
        let cells = core.work().cells;
        let rr = if cells > 0 {
            stride_deal(0, cells, &mut pu_cells)
        } else {
            0
        };
        Ok(StreamSession { core, pu_cells, rr })
    }
}

/// Append one sample to each of N sessions through **shared** row tiles
/// (the cross-stream analogue of [`StreamSession::extend`]'s blocked
/// path): all members must agree on `(m, excl)`, and each member's
/// resulting state is bit-identical to an isolated
/// [`StreamSession::append`] of the same sample — see
/// [`crate::mp::stampi::append_group`] for the engine-level contract.
/// Per-PU cell attribution stays per-member (each member deals its own
/// row's cells to its own fleet view), so the load-balance evidence is
/// unchanged by coalescing.
///
/// Returns the engine report: per-member completed windows, per-member
/// evaluated cells, and the lane widths of the shared sub-tiles.
pub fn append_group<T: Real>(
    members: &mut [(&mut StreamSession<T>, T)],
) -> crate::mp::stampi::GroupAppendReport {
    let mut cores: Vec<(&mut Stampi<T>, T)> = members
        .iter_mut()
        .map(|(s, x)| (&mut s.core, *x))
        .collect();
    let report = crate::mp::stampi::append_group(&mut cores);
    drop(cores);
    for ((s, _), &cells) in members.iter_mut().zip(&report.cells) {
        if cells > 0 {
            s.rr = stride_deal(s.rr, cells, &mut s.pu_cells);
        }
    }
    report
}

/// Deal `cells` to the PUs: the whole share to everyone, the remainder to
/// `rem` PUs starting at the rotating cursor `rr`.  Returns the advanced
/// cursor, so cumulative loads never diverge by more than one cell.
fn stride_deal(rr: usize, cells: u64, pu_cells: &mut [u64]) -> usize {
    let pus = pu_cells.len();
    let full = cells / pus as u64;
    for c in pu_cells.iter_mut() {
        *c += full;
    }
    let rem = (cells % pus as u64) as usize;
    for k in 0..rem {
        pu_cells[(rr + k) % pus] += 1;
    }
    (rr + rem) % pus
}

/// Execute every PU's band-tile work list on `threads` host threads.
/// Each tile runs through the kernel's multi-lane band path
/// ([`compute_band_n`]); returns one (private profile, work) per
/// *thread* (merging is associative and the per-PU cell counts are
/// preserved separately).
fn run_pus<T: Real>(
    t: &[T],
    st: &crate::timeseries::WindowStats<T>,
    sched: &BandedSchedule,
    excl: usize,
    threads: usize,
) -> (Vec<(MatrixProfile<T>, WorkStats)>, Vec<u64>) {
    let nw = sched.nw;
    let m = st.m;
    let pus = sched.per_pu.len();
    let threads = threads.clamp(1, pus.max(1));

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for tid in 0..threads {
            let sched = &sched;
            let st = &st;
            handles.push(scope.spawn(move || {
                let mut local = MatrixProfile::new_inf(nw, m, excl);
                let mut work = WorkStats::default();
                let mut cells: Vec<(usize, u64)> = Vec::new();
                // PU p runs on thread p % threads — round-robin, like the
                // paper's static PU placement.
                for p in (tid..pus).step_by(threads) {
                    let before = work.cells;
                    for tile in &sched.per_pu[p] {
                        compute_band_n(t, st, tile.d0, tile.width, &mut local, &mut work);
                    }
                    cells.push((p, work.cells - before));
                }
                (local, work, cells)
            }));
        }
        let mut locals = Vec::with_capacity(threads);
        let mut pu_cells = vec![0u64; pus];
        for h in handles {
            let (local, work, cells) = h.join().expect("PU thread panicked");
            for (p, c) in cells {
                pu_cells[p] = c;
            }
            locals.push((local, work));
        }
        (locals, pu_cells)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::brute;
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(41);
        let t: Vec<f64> = rng.gauss_vec(512);
        let engine = NatsaEngine::new(NatsaConfig::default());
        let out = engine.compute(&t, 16).unwrap();
        let want = brute::matrix_profile(&t, MpConfig::new(16)).unwrap();
        assert!(out.profile.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn order_does_not_change_result() {
        let mut rng = Rng::new(42);
        let t: Vec<f64> = rng.gauss_vec(400);
        let seq = NatsaEngine::new(NatsaConfig::default().with_order(Order::Sequential))
            .compute(&t, 12)
            .unwrap();
        let rnd = NatsaEngine::new(NatsaConfig::default().with_order(Order::Random(7)))
            .compute(&t, 12)
            .unwrap();
        assert!(seq.profile.max_abs_diff(&rnd.profile) < 1e-12);
        assert_eq!(seq.profile.i, rnd.profile.i);
    }

    #[test]
    fn prop_pu_count_invariance() {
        check("natsa-pu-invariance", 8, |rng: &mut Rng| {
            let n = rng.range(150, 400);
            let m = rng.range(6, 24);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let base = NatsaEngine::new(NatsaConfig::default().with_pus(1).with_threads(1))
                .compute(&t, m)
                .unwrap();
            for pus in [2, 7, 48, 64] {
                let out = NatsaEngine::new(NatsaConfig::default().with_pus(pus))
                    .compute(&t, m)
                    .unwrap();
                assert!(
                    out.profile.max_abs_diff(&base.profile) < 1e-12,
                    "pus={pus}"
                );
            }
        });
    }

    #[test]
    fn pu_loads_are_balanced() {
        let mut rng = Rng::new(44);
        let t: Vec<f64> = rng.gauss_vec(4000);
        let out = NatsaEngine::new(NatsaConfig::default())
            .compute(&t, 32)
            .unwrap();
        // banded schedule: whole coarse-tile-pair rounds are exactly
        // balanced; the fine tail quantizes at one diagonal-pair per PU
        assert!(out.schedule_imbalance < 1.03, "{}", out.schedule_imbalance);
        let max = *out.pu_cells.iter().max().unwrap() as f64;
        let min = *out.pu_cells.iter().min().unwrap() as f64;
        assert!(max / min < 1.05, "PU cells {max} vs {min}");
        let total: u64 = out.pu_cells.iter().sum();
        assert_eq!(total, out.work.cells);
    }

    #[test]
    fn finds_planted_motif_and_discord() {
        let (t, ev) = generate_with_event::<f32>(Pattern::PlantedMotif, 2048, 5);
        let out = NatsaEngine::new(NatsaConfig::default())
            .compute(&t, 32)
            .unwrap();
        if let PlantedEvent::Motif { a, b, .. } = ev {
            // f32 Eq.1 cancellation leaves O(sqrt(2m*eps)) residue
            assert!(out.profile.p[a] < 0.05, "p[a] = {}", out.profile.p[a]);
            assert_eq!(out.profile.i[a], b as i64);
        } else {
            unreachable!()
        }
    }

    #[test]
    fn rejects_degenerate_input() {
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
        assert!(engine.compute(&[1.0; 14], 12).is_err()); // nw(3) <= excl(3)
        assert!(engine.compute(&[1.0; 100], 2).is_err()); // m too small
    }

    #[test]
    fn stream_session_matches_batch_compute() {
        let mut rng = Rng::new(46);
        let t: Vec<f64> = rng.gauss_vec(600);
        let m = 16;
        let engine = NatsaEngine::new(NatsaConfig::default());
        let batch = engine.compute(&t, m).unwrap();
        let mut session = engine.open_stream(m).unwrap();
        assert_eq!(session.extend(&t), 600 - m + 1);
        let streamed = session.profile();
        assert!(streamed.max_abs_diff(&batch.profile) < 1e-7);
        // identical pair coverage => identical cell counts
        assert_eq!(session.work().cells, batch.work.cells);
    }

    #[test]
    fn stream_session_pu_accounting_is_balanced_and_consistent() {
        let mut rng = Rng::new(47);
        let t: Vec<f64> = rng.gauss_vec(4000);
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default()); // 48 PUs
        let mut session = engine.open_stream(32).unwrap();
        session.extend(&t);
        assert_eq!(session.pu_cells().len(), 48);
        let total: u64 = session.pu_cells().iter().sum();
        assert_eq!(total, session.work().cells);
        assert!(session.imbalance() < 1.01, "{}", session.imbalance());
        // the sim plane can cost this workload from the emitted stats
        assert!(session.work().flops(32) > 0);
    }

    #[test]
    fn stream_session_respects_engine_exclusion_override() {
        let mut rng = Rng::new(48);
        let t: Vec<f64> = rng.gauss_vec(300);
        let mut config = NatsaConfig::default();
        config.excl = Some(9);
        let mut session = NatsaEngine::new(config).open_stream(12).unwrap();
        session.extend(&t);
        let mp = session.profile();
        assert_eq!(mp.excl, 9);
        for (k, &j) in mp.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() >= 9);
            }
        }
    }

    #[test]
    fn stream_session_bounded_history() {
        let mut rng = Rng::new(49);
        let t: Vec<f64> = rng.gauss_vec(2000);
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
        let mut session = engine.open_stream_bounded(16, Some(256)).unwrap();
        session.extend(&t);
        assert!(session.first_window() >= 2000 - 256);
        assert_eq!(session.profile().len(), 256 - 16 + 1);
        // rejects bounds too small to ever admit a pair
        assert!(engine.open_stream_bounded(16, Some(10)).is_err());
    }

    #[test]
    fn young_stream_imbalance_is_finite() {
        // regression: before any cells were dealt (or while the remainder
        // cursor left some PUs untouched) min load 0 pinned the ratio at
        // infinity; idle PUs are now excluded and counted separately
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
        let session = engine.open_stream(16).unwrap();
        assert_eq!(session.imbalance(), 1.0);
        assert_eq!(session.idle_pus(), 48);
        let mut session = engine.open_stream(16).unwrap();
        session.extend(&crate::prop::Rng::new(50).gauss_vec(40));
        assert!(session.imbalance().is_finite(), "{}", session.imbalance());
    }

    #[test]
    fn shard_slice_divides_the_fleet_without_losing_pus() {
        let base = NatsaConfig::default().with_pus(48).with_threads(8);
        let slice = base.shard_slice(4, 0);
        assert_eq!(slice.pus, 12);
        assert_eq!(slice.threads, Some(2));
        // a non-dividing shard count deals the remainder to the first
        // shards: the slices must sum back to the whole fleet
        let pus: Vec<usize> = (0..5).map(|k| base.shard_slice(5, k).pus).collect();
        assert_eq!(pus, vec![10, 10, 10, 9, 9]);
        assert_eq!(pus.iter().sum::<usize>(), 48);
        let threads: usize = (0..5)
            .map(|k| base.shard_slice(5, k).threads.unwrap())
            .sum();
        assert_eq!(threads, 8);
        // never below one PU/thread, even with more shards than PUs
        let tiny = NatsaConfig::default().with_pus(2).with_threads(1).shard_slice(8, 7);
        assert_eq!(tiny.pus, 1);
        assert_eq!(tiny.threads, Some(1));
        // shards = 0 is treated as 1 (no division)
        assert_eq!(base.shard_slice(0, 0).pus, 48);
    }

    #[test]
    fn session_group_append_matches_isolated_and_keeps_attribution() {
        // The service-facing wrapper: shared tiles leave every member's
        // profile AND per-PU attribution exactly as isolated appends do
        // (each member deals its own row's cells to its own fleet view).
        let mut rng = Rng::new(58);
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default().with_pus(4));
        let n = 6usize;
        let steps = 80usize;
        let m = 12usize;
        let series: Vec<Vec<f64>> = (0..n).map(|_| rng.gauss_vec(steps)).collect();
        let mut grouped: Vec<StreamSession<f64>> =
            (0..n).map(|_| engine.open_stream(m).unwrap()).collect();
        let mut isolated: Vec<StreamSession<f64>> =
            (0..n).map(|_| engine.open_stream(m).unwrap()).collect();
        for step in 0..steps {
            let mut members: Vec<(&mut StreamSession<f64>, f64)> = grouped
                .iter_mut()
                .zip(&series)
                .map(|(s, t)| (s, t[step]))
                .collect();
            let report = append_group(&mut members);
            drop(members);
            assert!(report.widths.iter().all(|&w| w <= crate::mp::kernel::BAND));
            for (w, s) in isolated.iter_mut().enumerate() {
                s.append(series[w][step]);
            }
        }
        for (g, i) in grouped.iter().zip(&isolated) {
            let (pg, pi) = (g.profile(), i.profile());
            let bits = |p: &[f64]| p.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
            assert_eq!(bits(&pg.p), bits(&pi.p));
            assert_eq!(pg.i, pi.i);
            assert_eq!(g.work(), i.work());
            assert_eq!(g.pu_cells(), i.pu_cells());
            assert_eq!(g.pu_cells().iter().sum::<u64>(), g.work().cells);
        }
    }

    #[test]
    fn custom_exclusion_flows_through() {
        let mut rng = Rng::new(45);
        let t: Vec<f64> = rng.gauss_vec(300);
        let mut config = NatsaConfig::default();
        config.excl = Some(9);
        let out = NatsaEngine::new(config).compute(&t, 12).unwrap();
        assert_eq!(out.profile.excl, 9);
        for (k, &j) in out.profile.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() >= 9);
            }
        }
    }
}
