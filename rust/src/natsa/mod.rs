//! NATSA: the accelerator's host API and functional engine.
//!
//! This module is Algorithm 2 of the paper:
//!
//! ```text
//! function P, I <- NATSA(T, m, exc, conf)
//!     mu, sig <- precalculateMeanDev(T, m)          // host CPU
//!     PP, II  <- allocatePrivateProfiles(T, m, exc) // per-PU vectors
//!     idx     <- diagonalScheduling(T, m, exc)      // Section 4.2
//!     START_ACCELERATOR(T, m, exc, conf, idx, PP, II)
//!     P, I    <- reduction(PP, II)                  // host CPU
//! ```
//!
//! [`NatsaEngine`] executes the accelerator step with host threads standing
//! in for the 48 PUs (each PU's work list and private profile is preserved
//! 1:1, so schedules, load accounting and anytime behaviour are faithful;
//! only the physical substrate differs).  The PJRT-backed engine that runs
//! the *AOT Pallas kernels* per chunk lives in [`crate::coordinator`] and
//! reuses this module's scheduling and reduction.

pub mod anytime;
pub mod pu;
pub mod scheduler;

use crate::mp::scrimp::compute_diagonal;
use crate::mp::{MatrixProfile, MpConfig, WorkStats};
use crate::timeseries::sliding_stats;
use crate::Real;
use scheduler::Schedule;

/// Diagonal visiting order within each PU (Section 4.2, ways 1 and 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// Sequential: locality-friendly, forfeits the anytime property.
    Sequential,
    /// Random (seeded): preserves the anytime property.
    Random(u64),
}

/// Accelerator configuration (`conf` of Algorithm 2).
#[derive(Clone, Copy, Debug)]
pub struct NatsaConfig {
    /// Number of processing units (48 in the paper's HBM design).
    pub pus: usize,
    /// Host threads emulating the PU fleet (defaults to available
    /// parallelism; PU→thread mapping is round-robin).
    pub threads: Option<usize>,
    /// Diagonal order within each PU.
    pub order: Order,
    /// Exclusion-zone radius override (`exc`); `None` = m/4.
    pub excl: Option<usize>,
}

impl Default for NatsaConfig {
    fn default() -> Self {
        NatsaConfig {
            pus: 48,
            threads: None,
            order: Order::Sequential,
            excl: None,
        }
    }
}

impl NatsaConfig {
    pub fn with_pus(mut self, pus: usize) -> Self {
        self.pus = pus;
        self
    }

    pub fn with_order(mut self, order: Order) -> Self {
        self.order = order;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = Some(threads);
        self
    }

    fn host_threads(&self) -> usize {
        self.threads.unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4)
        })
    }
}

/// Result of a NATSA run.
#[derive(Clone, Debug)]
pub struct NatsaOutput<T> {
    /// The reduced profile `P`, `I`.
    pub profile: MatrixProfile<T>,
    /// Aggregate functional work (drives the timing models).
    pub work: WorkStats,
    /// Cells executed by each PU (load-balance evidence).
    pub pu_cells: Vec<u64>,
    /// The schedule that was executed.
    pub schedule_imbalance: f64,
}

/// The functional NATSA engine (native execution substrate).
pub struct NatsaEngine<T> {
    pub config: NatsaConfig,
    _marker: std::marker::PhantomData<T>,
}

impl<T: Real> NatsaEngine<T> {
    pub fn new(config: NatsaConfig) -> Self {
        NatsaEngine { config, _marker: std::marker::PhantomData }
    }

    /// Algorithm 2: compute the full matrix profile of `t` with window `m`.
    pub fn compute(&self, t: &[T], m: usize) -> crate::Result<NatsaOutput<T>> {
        let cfg = match self.config.excl {
            Some(e) => MpConfig::with_excl(m, e),
            None => MpConfig::new(m),
        };
        let nw = cfg.validate(t.len())?;
        let excl = cfg.exclusion();

        // Host: statistics precompute + diagonal scheduling.
        let st = sliding_stats(t, m);
        let mut sched = scheduler::schedule(nw, excl, self.config.pus);
        match self.config.order {
            Order::Sequential => sched.sequentialize(),
            Order::Random(seed) => sched.randomize(seed),
        }
        let imbalance = sched.imbalance();

        // Accelerator: PUs execute their work lists with private profiles.
        let (locals, pu_cells) = run_pus(t, &st, &sched, excl, self.config.host_threads());

        // Host: reduction of the private profiles.
        let mut profile = MatrixProfile::new_inf(nw, m, excl);
        let mut work = WorkStats::default();
        for (local, w) in &locals {
            profile.merge(local);
            work.add(w);
        }
        profile.sqrt_in_place(); // diagonals accumulate squared distances
        Ok(NatsaOutput { profile, work, pu_cells, schedule_imbalance: imbalance })
    }
}

/// Execute every PU's work list on `threads` host threads.  Returns one
/// (private profile, work) per *thread* (merging is associative and the
/// per-PU cell counts are preserved separately).
fn run_pus<T: Real>(
    t: &[T],
    st: &crate::timeseries::WindowStats<T>,
    sched: &Schedule,
    excl: usize,
    threads: usize,
) -> (Vec<(MatrixProfile<T>, WorkStats)>, Vec<u64>) {
    let nw = sched.nw;
    let m = st.m;
    let pus = sched.per_pu.len();
    let threads = threads.clamp(1, pus.max(1));

    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for tid in 0..threads {
            let sched = &sched;
            let st = &st;
            handles.push(scope.spawn(move || {
                let mut local = MatrixProfile::new_inf(nw, m, excl);
                let mut work = WorkStats::default();
                let mut cells: Vec<(usize, u64)> = Vec::new();
                // PU p runs on thread p % threads — round-robin, like the
                // paper's static PU placement.
                for p in (tid..pus).step_by(threads) {
                    let before = work.cells;
                    for &d in &sched.per_pu[p] {
                        compute_diagonal(t, st, d, &mut local, &mut work);
                    }
                    cells.push((p, work.cells - before));
                }
                (local, work, cells)
            }));
        }
        let mut locals = Vec::with_capacity(threads);
        let mut pu_cells = vec![0u64; pus];
        for h in handles {
            let (local, work, cells) = h.join().expect("PU thread panicked");
            for (p, c) in cells {
                pu_cells[p] = c;
            }
            locals.push((local, work));
        }
        (locals, pu_cells)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::brute;
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    #[test]
    fn matches_brute_force() {
        let mut rng = Rng::new(41);
        let t: Vec<f64> = rng.gauss_vec(512);
        let engine = NatsaEngine::new(NatsaConfig::default());
        let out = engine.compute(&t, 16).unwrap();
        let want = brute::matrix_profile(&t, MpConfig::new(16)).unwrap();
        assert!(out.profile.max_abs_diff(&want) < 1e-8);
    }

    #[test]
    fn order_does_not_change_result() {
        let mut rng = Rng::new(42);
        let t: Vec<f64> = rng.gauss_vec(400);
        let seq = NatsaEngine::new(NatsaConfig::default().with_order(Order::Sequential))
            .compute(&t, 12)
            .unwrap();
        let rnd = NatsaEngine::new(NatsaConfig::default().with_order(Order::Random(7)))
            .compute(&t, 12)
            .unwrap();
        assert!(seq.profile.max_abs_diff(&rnd.profile) < 1e-12);
        assert_eq!(seq.profile.i, rnd.profile.i);
    }

    #[test]
    fn prop_pu_count_invariance() {
        check("natsa-pu-invariance", 8, |rng: &mut Rng| {
            let n = rng.range(150, 400);
            let m = rng.range(6, 24);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let base = NatsaEngine::new(NatsaConfig::default().with_pus(1).with_threads(1))
                .compute(&t, m)
                .unwrap();
            for pus in [2, 7, 48, 64] {
                let out = NatsaEngine::new(NatsaConfig::default().with_pus(pus))
                    .compute(&t, m)
                    .unwrap();
                assert!(
                    out.profile.max_abs_diff(&base.profile) < 1e-12,
                    "pus={pus}"
                );
            }
        });
    }

    #[test]
    fn pu_loads_are_balanced() {
        let mut rng = Rng::new(44);
        let t: Vec<f64> = rng.gauss_vec(4000);
        let out = NatsaEngine::new(NatsaConfig::default())
            .compute(&t, 32)
            .unwrap();
        // 48 PUs x ~41.3 pairs: quantization allows one extra pair per PU
        assert!(out.schedule_imbalance < 1.03, "{}", out.schedule_imbalance);
        let max = *out.pu_cells.iter().max().unwrap() as f64;
        let min = *out.pu_cells.iter().min().unwrap() as f64;
        assert!(max / min < 1.05, "PU cells {max} vs {min}");
        let total: u64 = out.pu_cells.iter().sum();
        assert_eq!(total, out.work.cells);
    }

    #[test]
    fn finds_planted_motif_and_discord() {
        let (t, ev) = generate_with_event::<f32>(Pattern::PlantedMotif, 2048, 5);
        let out = NatsaEngine::new(NatsaConfig::default())
            .compute(&t, 32)
            .unwrap();
        if let PlantedEvent::Motif { a, b, .. } = ev {
            // f32 Eq.1 cancellation leaves O(sqrt(2m*eps)) residue
            assert!(out.profile.p[a] < 0.05, "p[a] = {}", out.profile.p[a]);
            assert_eq!(out.profile.i[a], b as i64);
        } else {
            unreachable!()
        }
    }

    #[test]
    fn rejects_degenerate_input() {
        let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
        assert!(engine.compute(&[1.0; 14], 12).is_err()); // nw(3) <= excl(3)
        assert!(engine.compute(&[1.0; 100], 2).is_err()); // m too small
    }

    #[test]
    fn custom_exclusion_flows_through() {
        let mut rng = Rng::new(45);
        let t: Vec<f64> = rng.gauss_vec(300);
        let mut config = NatsaConfig::default();
        config.excl = Some(9);
        let out = NatsaEngine::new(config).compute(&t, 12).unwrap();
        assert_eq!(out.profile.excl, 9);
        for (k, &j) in out.profile.i.iter().enumerate() {
            if j >= 0 {
                assert!((k as i64 - j).unsigned_abs() >= 9);
            }
        }
    }
}
