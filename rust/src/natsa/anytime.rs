//! Anytime (interruptible) execution — the property NATSA's scheduler is
//! designed to preserve (Sections 1, 4.2).
//!
//! Matrix profile is an *anytime* algorithm: interrupt it and the partial
//! profile is still a valid upper bound whose minima are true motifs found
//! so far.  NATSA keeps this property under parallelism by (a) giving each
//! PU a balanced mix of long and short diagonals and (b) optionally
//! randomizing each PU's visiting order, so any prefix of execution covers
//! the distance matrix roughly uniformly.
//!
//! [`run_anytime`] executes PU work lists round-robin, one **band tile**
//! per PU per turn (the tile is the interruption quantum — the same work
//! unit the band-granular scheduler deals and the kernel executes in one
//! call), checking the [`Budget`] after *every* quantum.  Checking per
//! quantum matters: budgets used to be checked only between whole PU
//! rounds (`Flag`) or whole diagonals (`Cells`), so a pre-set
//! interruption flag still executed up to `pus` full diagonals before
//! stopping — on a 48-PU fleet, ~48x the promised interruption latency.
//! Now an interruption costs at most one in-flight tile.

use crate::sync::atomic::{AtomicBool, Ordering};

use crate::mp::kernel::compute_band_n;
use crate::mp::{total_cells, MatrixProfile, MpConfig, WorkStats};
use crate::natsa::{scheduler, NatsaConfig, Order};
use crate::timeseries::sliding_stats;
use crate::Real;

/// When to stop an anytime run.
#[derive(Debug)]
pub enum Budget<'a> {
    /// Stop after at least this many cells have been computed.
    Cells(u64),
    /// Stop after this fraction of the total work (0, 1].
    Fraction(f64),
    /// Stop when the flag becomes true (external interruption).
    Flag(&'a AtomicBool),
    /// Run to completion.
    Unlimited,
}

/// A partial matrix profile plus progress accounting.
#[derive(Clone, Debug)]
pub struct PartialProfile<T> {
    pub profile: MatrixProfile<T>,
    pub work: WorkStats,
    /// Fraction of admissible cells covered (0, 1].
    pub progress: f64,
    /// Diagonals fully processed.
    pub diagonals_done: usize,
}

/// Interruptible NATSA execution (single-threaded: the anytime semantics
/// are about *coverage order*, which is identical on any substrate).
pub fn run_anytime<T: Real>(
    t: &[T],
    m: usize,
    config: &NatsaConfig,
    budget: Budget<'_>,
) -> crate::Result<PartialProfile<T>> {
    let cfg = match config.excl {
        Some(e) => MpConfig::with_excl(m, e),
        None => MpConfig::new(m),
    };
    let nw = cfg.validate(t.len())?;
    let excl = cfg.exclusion();
    let st = sliding_stats(t, m);
    let total = total_cells(nw, excl);

    let mut sched = scheduler::schedule_banded(nw, excl, config.pus);
    match config.order {
        Order::Sequential => sched.sequentialize(),
        Order::Random(seed) => sched.randomize(seed),
    }

    let stop_at = match budget {
        Budget::Cells(c) => c,
        Budget::Fraction(f) => {
            anyhow::ensure!(f > 0.0 && f <= 1.0, "fraction must be in (0,1], got {f}");
            (total as f64 * f).ceil() as u64
        }
        Budget::Flag(_) | Budget::Unlimited => u64::MAX,
    };

    let mut mp = MatrixProfile::new_inf(nw, m, excl);
    let mut work = WorkStats::default();
    let mut done = 0usize;
    let longest = sched.per_pu.iter().map(|l| l.len()).max().unwrap_or(0);

    'outer: for round in 0..longest {
        for list in &sched.per_pu {
            if let Some(&tile) = list.get(round) {
                compute_band_n(t, &st, tile.d0, tile.width, &mut mp, &mut work);
                done += tile.width;
                // Budget check per work quantum (tile), never coarser:
                // an interruption — cell budget or external flag — must
                // cost at most the one tile already in flight.
                if work.cells >= stop_at {
                    break 'outer;
                }
                if let Budget::Flag(flag) = budget {
                    if flag.load(Ordering::Relaxed) {
                        break 'outer;
                    }
                }
            }
        }
    }

    mp.sqrt_in_place(); // diagonals accumulate squared distances
    Ok(PartialProfile {
        profile: mp,
        progress: work.cells as f64 / total as f64,
        work,
        diagonals_done: done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::scrimp;
    use crate::prop::{check, Rng};
    use crate::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

    fn config_random() -> NatsaConfig {
        NatsaConfig::default().with_order(Order::Random(99))
    }

    #[test]
    fn unlimited_equals_full_run() {
        let mut rng = Rng::new(51);
        let t: Vec<f64> = rng.gauss_vec(400);
        let out = run_anytime(&t, 16, &config_random(), Budget::Unlimited).unwrap();
        let want = scrimp::matrix_profile(&t, MpConfig::new(16)).unwrap();
        assert!((out.progress - 1.0).abs() < 1e-12);
        assert!(out.profile.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn partial_is_upper_bound_of_final() {
        check("anytime-upper-bound", 8, |rng: &mut Rng| {
            let n = rng.range(200, 500);
            let t: Vec<f64> = rng.gauss_vec(n);
            let m = 12;
            let frac = 0.1 + rng.f64() * 0.8;
            let part = run_anytime(&t, m, &config_random(), Budget::Fraction(frac)).unwrap();
            let full = scrimp::matrix_profile(&t, MpConfig::new(m)).unwrap();
            for k in 0..full.len() {
                assert!(
                    part.profile.p[k] >= full.p[k] - 1e-12,
                    "partial P[{k}]={} below final {}",
                    part.profile.p[k],
                    full.p[k]
                );
            }
        });
    }

    #[test]
    fn progress_tracks_budget() {
        let mut rng = Rng::new(52);
        let t: Vec<f64> = rng.gauss_vec(600);
        let out = run_anytime(&t, 16, &config_random(), Budget::Fraction(0.25)).unwrap();
        assert!(out.progress >= 0.25, "{}", out.progress);
        // at most one band tile of overshoot (the work quantum)
        assert!(out.progress < 0.30, "{}", out.progress);
    }

    #[test]
    fn motif_found_early_with_random_order() {
        // The headline anytime claim: a strong motif is discovered long
        // before full coverage when diagonals are visited randomly.
        let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, 3000, 6);
        let (a, b) = match ev {
            PlantedEvent::Motif { a, b, .. } => (a, b),
            _ => unreachable!(),
        };
        let m = 64;
        // 15% of the work, random order: the motif diagonal b-a is hit
        // with high probability because every PU samples uniformly.
        let part = run_anytime(&t, m, &config_random(), Budget::Fraction(0.15)).unwrap();
        let hit = part.profile.p[a] < 1e-3 || {
            // if the exact diagonal wasn't drawn, the profile may still
            // be partial there; accept but require eventual discovery
            let full = run_anytime(&t, m, &config_random(), Budget::Unlimited).unwrap();
            full.profile.p[a] < 1e-3 && full.profile.i[a] == b as i64
        };
        assert!(hit);
    }

    #[test]
    fn flag_interruption_stops_early() {
        let mut rng = Rng::new(53);
        let t: Vec<f64> = rng.gauss_vec(800);
        let flag = AtomicBool::new(true); // pre-set: stop after one quantum
        let out = run_anytime(&t, 16, &config_random(), Budget::Flag(&flag)).unwrap();
        assert!(out.progress < 1.0);
        assert!(out.diagonals_done >= 1);
    }

    #[test]
    fn preset_flag_executes_at_most_one_quantum() {
        // Regression: the flag used to be checked only between whole PU
        // rounds, so a pre-set flag still executed up to `pus` (48) full
        // diagonals.  The budget is now honored per work quantum: a
        // pre-set flag stops after the single tile already in flight.
        use crate::mp::kernel::BAND;
        let mut rng = Rng::new(55);
        let t: Vec<f64> = rng.gauss_vec(800);
        let m = 16;
        let nw = 800 - m + 1;
        let excl = m / 4;
        let flag = AtomicBool::new(true);
        let out = run_anytime(&t, m, &config_random(), Budget::Flag(&flag)).unwrap();
        // at most one tile: <= BAND diagonals, <= BAND longest-diagonal
        // cells (conservative bound on any tile in the schedule)
        assert!(
            out.diagonals_done >= 1 && out.diagonals_done <= BAND,
            "{} diagonals after pre-set flag",
            out.diagonals_done
        );
        let max_tile_cells: u64 = (0..BAND).map(|dd| (nw - excl - dd) as u64).sum();
        assert!(
            out.work.cells <= max_tile_cells,
            "{} cells after pre-set flag (one quantum is <= {max_tile_cells})",
            out.work.cells
        );
        // the same granularity must hold for cell budgets: a 1-cell
        // budget stops after one tile too
        let out = run_anytime(&t, m, &config_random(), Budget::Cells(1)).unwrap();
        assert!(out.work.cells <= max_tile_cells, "{}", out.work.cells);
    }

    #[test]
    fn invalid_fraction_rejected() {
        let t: Vec<f64> = Rng::new(54).gauss_vec(100);
        assert!(run_anytime(&t, 8, &config_random(), Budget::Fraction(0.0)).is_err());
        assert!(run_anytime(&t, 8, &config_random(), Budget::Fraction(1.5)).is_err());
    }
}
