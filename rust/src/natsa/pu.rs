//! NATSA processing-unit datapath: functional model + work descriptors.
//!
//! Paper Section 4.1 / Fig. 5: a PU is a control FSM around four shared-FPU
//! hardware components —
//!
//! * **DPU**  — dot product of the first window pair of a diagonal,
//! * **DCU**  — z-norm Euclidean distance (Eq. 1),
//! * **PUU**  — profile/index min-update,
//! * **DPUU** — incremental dot-product update (Eq. 2), replicated for
//!   vectorization and pipelined with DCU + PUU.
//!
//! This module gives that PU two faces:
//!
//! 1. [`PuDatapath`] — a *functional* cycle-by-cycle state machine that
//!    executes the six execution-flow steps of Section 4.1 literally
//!    (used by tests to pin the hardware semantics to SCRIMP's math, and
//!    by `examples/pu_trace.rs` to show the pipeline schedule);
//! 2. [`PuDesign`] + [`ChunkWork`] — the *descriptor* face: per-chunk
//!    cycle and DRAM-traffic accounting consumed by the timing/energy
//!    models in [`crate::sim::accel`] (gem5-Aladdin substitute).

use crate::mp::kernel;
use crate::mp::{MatrixProfile, WorkStats};
use crate::natsa::scheduler::BandTile;
use crate::timeseries::WindowStats;
use crate::Real;

/// DPUU→DCU→PUU pipeline depth (Fig. 5): the fill cycles charged once
/// per chunk.  This is THE closed-form constant — both the functional
/// [`PuTrace`] and the descriptor [`ChunkWork`] charge it, so the two
/// faces of the PU model can never disagree on the cycle count of the
/// same work (they used to: the trace charged a `log2(lanes)` tree depth
/// where the descriptor charged 12, skewing `examples/pu_trace.rs`
/// against the [`crate::sim::accel`] timing model).
pub const PIPE_FILL: u64 = 12;

/// Static design parameters of one PU (paper Table 3, per-PU columns).
#[derive(Clone, Copy, Debug)]
pub struct PuDesign {
    /// Vector lanes: diagonal cells advanced per cycle at II=1.
    pub lanes: usize,
    /// FP multiplier / adder counts (Table 3).
    pub fp_mults: usize,
    pub fp_adds: usize,
    pub int_adds: usize,
    pub bitwise: usize,
    pub registers: usize,
    /// Private scratchpad for window size + configuration (Section 4.1).
    pub scratchpad_bytes: usize,
    /// Clock (GHz) — 1 GHz in the paper.
    pub freq_ghz: f64,
    /// HBM channel share per PU (GB/s) — 5 GB/s in Table 3.
    pub mem_bw_gbs: f64,
    /// Peak dynamic power (W) and area (mm², 45 nm) per Table 3.
    pub peak_power_w: f64,
    pub area_mm2: f64,
    /// Element width this design processes.
    pub elem_bytes: usize,
}

impl PuDesign {
    /// Double-precision PU (Table 3 column PU-DP).
    pub fn dp() -> Self {
        PuDesign {
            lanes: 8,
            fp_mults: 16,
            fp_adds: 14,
            int_adds: 16,
            bitwise: 2,
            registers: 108,
            scratchpad_bytes: 1024,
            freq_ghz: 1.0,
            mem_bw_gbs: 5.0,
            peak_power_w: 0.1,
            area_mm2: 1.62,
            elem_bytes: 8,
        }
    }

    /// Single-precision PU (Table 3 column PU-SP).
    pub fn sp() -> Self {
        PuDesign {
            lanes: 16,
            fp_mults: 64,
            fp_adds: 36,
            int_adds: 64,
            bitwise: 2,
            registers: 267,
            scratchpad_bytes: 1024,
            freq_ghz: 1.0,
            mem_bw_gbs: 5.0,
            peak_power_w: 0.08,
            area_mm2: 1.51,
            elem_bytes: 4,
        }
    }

    /// Pick the design matching an element type.
    pub fn for_dtype(dtype: &str) -> Self {
        match dtype {
            "f32" => Self::sp(),
            _ => Self::dp(),
        }
    }

    /// Peak cells/second of one PU (vector lanes at II=1).
    pub fn peak_cells_per_sec(&self) -> f64 {
        self.lanes as f64 * self.freq_ghz * 1e9
    }

    /// Cycles of one O(m) seed dot product (the DPU burst): `m/lanes`
    /// vectorized multiply-adds plus the `log2(lanes)` reduction-tree
    /// depth.  The single closed form shared by [`PuTrace`] and
    /// [`ChunkWork::cycles`].
    pub fn seed_dot_cycles(&self, m: usize) -> u64 {
        (m as u64).div_ceil(self.lanes as u64) + u64::from((self.lanes as u64).trailing_zeros())
    }
}

/// One unit of PU work: a contiguous run of cells on a band tile of
/// adjacent diagonals (width 1 = the classic single-diagonal chunk).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkWork {
    /// Cells computed (incremental, Eq. 2 path).
    pub cells: u64,
    /// O(m) DPU seed dot products at the head of this chunk — one per
    /// diagonal the chunk *begins* (a [`BAND`](crate::mp::kernel::BAND)
    /// tile charges its width, a continuation chunk charges 0).
    pub first_dots: u64,
    /// Window length.
    pub m: usize,
}

impl ChunkWork {
    /// PU cycles under the unified closed-form model: one DPU burst per
    /// seed dot ([`PuDesign::seed_dot_cycles`]), one pipeline fill
    /// ([`PIPE_FILL`]), then II=1 vector iterations over the cells.
    /// Pinned equal to the functional [`PuTrace::cycles`] of the same
    /// work by `trace_and_descriptor_agree_on_cycles`.
    pub fn cycles(&self, d: &PuDesign) -> u64 {
        self.first_dots * d.seed_dot_cycles(self.m)
            + self.cells.div_ceil(d.lanes as u64)
            + PIPE_FILL
    }

    /// DRAM bytes moved for this chunk.  Per cell the PU streams the two
    /// series points of Eq. 2, four statistics, and the two profile
    /// entries + indices it may update (Section 4.2 data mapping: profile
    /// vectors are PU-private but DRAM-resident; only `m`/config live in
    /// the 1 KB scratchpad).
    pub fn traffic_bytes(&self, d: &PuDesign) -> u64 {
        let e = d.elem_bytes as u64;
        let per_cell = 2 * e      // t[i+m-1], t[j+m-1] (t[i-1],t[j-1] reuse the stream)
            + 4 * e               // mu_i, mu_j, inv_msig_i, inv_msig_j
            + 2 * e               // P_i, P_j read
            + e;                  // amortized P/I write-back
        self.first_dots * 2 * self.m as u64 * e + self.cells * per_cell
    }

    /// FLOPs executed (Eq. 2: 4, Eq. 1: ~7, compares: 2 per cell).
    pub fn flops(&self) -> u64 {
        self.first_dots * 2 * self.m as u64 + self.cells * 13
    }
}

/// Pipeline stage occupancy, one entry per step of Section 4.1.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PuTrace {
    pub dpu_cycles: u64,
    pub dpuu_cycles: u64,
    pub dcu_cycles: u64,
    pub puu_cycles: u64,
}

impl PuTrace {
    /// Total latency of the traced run under the unified closed-form
    /// model: the DPU bursts, one pipeline fill, then one II=1 vector
    /// group per cycle through the deepest pipelined stage.  By
    /// construction equal to [`ChunkWork::cycles`] for the same work —
    /// the functional trace and the descriptor model can no longer
    /// charge different cycles for the same diagonal.
    pub fn cycles(&self) -> u64 {
        self.dpu_cycles + PIPE_FILL + self.dcu_cycles.max(self.puu_cycles)
    }
}

/// Functional PU: executes one diagonal exactly as the Section 4.1 flow
/// describes, updating a (private) profile and producing a stage trace.
pub struct PuDatapath<'a, T> {
    pub design: PuDesign,
    t: &'a [T],
    st: &'a WindowStats<T>,
}

impl<'a, T: Real> PuDatapath<'a, T> {
    pub fn new(design: PuDesign, t: &'a [T], st: &'a WindowStats<T>) -> Self {
        PuDatapath { design, t, st }
    }

    /// Execute the band tile `tile` (adjacent diagonals
    /// `tile.d0..tile.d0+tile.width`) against private profile `pp`
    /// following the six steps of Section 4.1, width lanes at a time.
    /// Returns the stage trace and work stats.
    ///
    /// The arithmetic is [`kernel::compute_band_n`] — the exact cell
    /// math every other engine runs, so a PU-fleet profile is
    /// bit-identical to a SCRIMP/STOMP one.  The stage occupancy is
    /// charged in closed form under the unified model: one DPU burst per
    /// diagonal in the tile (steps 1-3: seed dots, first distances,
    /// first updates), then `lanes` cells per DPUU/DCU/PUU cycle at II=1
    /// over the pipelined cells (steps 4-6); [`PuTrace::cycles`] equals
    /// [`ChunkWork::cycles`] of the same work by construction.
    ///
    /// PERF CONTRACT: `pp` accumulates **squared** distances; callers
    /// finalize with one [`MatrixProfile::sqrt_in_place`] after all
    /// tiles merge.
    pub fn run_band(&self, tile: BandTile, pp: &mut MatrixProfile<T>) -> (PuTrace, WorkStats) {
        let m = self.st.m;
        let lanes = self.design.lanes as u64;
        let mut work = WorkStats::default();

        // Steps 1-6, functionally: the unified kernel (closed-form stats).
        kernel::compute_band_n(self.t, self.st, tile.d0, tile.width, pp, &mut work);

        // Stage occupancy in closed form.  Step 1 (DPU): one vectorized
        // tree reduce per diagonal's m-point seed dot.  Steps 4-6
        // (DPUU->DCU->PUU): `lanes` cells per cycle at II=1; the width
        // seed cells skip the DPUU (their dot IS the seed).
        let vec_groups = work.cells.div_ceil(lanes);
        let trace = PuTrace {
            dpu_cycles: tile.width as u64 * self.design.seed_dot_cycles(m),
            dpuu_cycles: (work.cells - tile.width as u64).div_ceil(lanes),
            dcu_cycles: vec_groups,
            puu_cycles: vec_groups,
        };
        (trace, work)
    }

    /// Execute one diagonal — [`Self::run_band`] at width 1, the classic
    /// Section 4.1 flow.
    pub fn run_diagonal(&self, d: usize, pp: &mut MatrixProfile<T>) -> (PuTrace, WorkStats) {
        self.run_band(BandTile { d0: d, width: 1 }, pp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{scrimp, MpConfig};
    use crate::prop::{check, Rng};
    use crate::timeseries::sliding_stats;

    #[test]
    fn table3_per_pu_parameters() {
        let dp = PuDesign::dp();
        assert_eq!(dp.fp_mults, 16);
        assert_eq!(dp.fp_adds, 14);
        assert_eq!(dp.registers, 108);
        assert!((dp.mem_bw_gbs - 5.0).abs() < 1e-12);
        assert!((dp.peak_power_w - 0.1).abs() < 1e-12);
        let sp = PuDesign::sp();
        assert_eq!(sp.fp_mults, 64);
        assert_eq!(sp.registers, 267);
        assert!(sp.area_mm2 < dp.area_mm2);
    }

    #[test]
    fn datapath_matches_scrimp_per_diagonal() {
        check("pu-vs-scrimp", 10, |rng: &mut Rng| {
            let n = rng.range(80, 400);
            let m = rng.range(4, 20);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let st = sliding_stats(&t, m);
            let nw = st.len();
            let excl = (m / 4).max(1);
            let design = PuDesign::dp();
            let dp = PuDatapath::new(design, &t, &st);

            let mut via_pu = MatrixProfile::new_inf(nw, m, excl);
            let mut via_scrimp = MatrixProfile::new_inf(nw, m, excl);
            let mut w = WorkStats::default();
            for d in excl..nw {
                dp.run_diagonal(d, &mut via_pu);
                scrimp::compute_diagonal(&t, &st, d, &mut via_scrimp, &mut w);
            }
            // both paths run the unified kernel and defer the sqrt
            via_pu.sqrt_in_place();
            via_scrimp.sqrt_in_place();
            assert!(via_pu.max_abs_diff(&via_scrimp) == 0.0);
            assert_eq!(via_pu.i, via_scrimp.i);
        });
    }

    #[test]
    fn full_profile_through_datapath_matches_reference() {
        let mut rng = Rng::new(31);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let st = sliding_stats(&t, 12);
        let nw = st.len();
        let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
        let mut mp = MatrixProfile::new_inf(nw, 12, cfg.exclusion());
        for d in cfg.exclusion()..nw {
            dp.run_diagonal(d, &mut mp);
        }
        mp.sqrt_in_place(); // the datapath defers the sqrt like every engine
        let want = scrimp::matrix_profile(&t, cfg).unwrap();
        assert!(mp.max_abs_diff(&want) == 0.0);
    }

    #[test]
    fn chunk_cycles_scale_with_lanes() {
        let w = ChunkWork { cells: 1024, first_dots: 0, m: 128 };
        let dp_cycles = w.cycles(&PuDesign::dp());
        let sp_cycles = w.cycles(&PuDesign::sp());
        assert!(sp_cycles < dp_cycles);
        assert_eq!(w.cycles(&PuDesign::dp()), 1024 / 8 + PIPE_FILL);
    }

    #[test]
    fn first_dots_add_startup() {
        let a = ChunkWork { cells: 100, first_dots: 0, m: 256 };
        let b = ChunkWork { cells: 100, first_dots: 1, m: 256 };
        let band = ChunkWork { cells: 100, first_dots: 8, m: 256 };
        let d = PuDesign::dp();
        assert!(b.cycles(&d) > a.cycles(&d));
        assert!(b.traffic_bytes(&d) > a.traffic_bytes(&d));
        // a band tile charges one DPU burst per diagonal it begins
        assert_eq!(
            band.cycles(&d) - a.cycles(&d),
            8 * d.seed_dot_cycles(256)
        );
        assert_eq!(band.flops() - a.flops(), 8 * 2 * 256);
    }

    #[test]
    fn sp_traffic_half_of_dp() {
        let w = ChunkWork { cells: 1000, first_dots: 0, m: 64 };
        assert_eq!(
            w.traffic_bytes(&PuDesign::dp()),
            2 * w.traffic_bytes(&PuDesign::sp())
        );
    }

    #[test]
    fn trace_pipeline_counts() {
        let mut rng = Rng::new(33);
        let t: Vec<f64> = rng.gauss_vec(200);
        let st = sliding_stats(&t, 8);
        let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
        let nw = st.len();
        let mut pp = MatrixProfile::new_inf(nw, 8, 2);
        let (trace, work) = dp.run_diagonal(10, &mut pp);
        // one DPU burst, then II=1 vector groups over the cells (the
        // seed cell skips the DPUU: its dot product IS the seed)
        let len = (nw - 10) as u64;
        assert_eq!(work.cells, len);
        assert_eq!(trace.dpu_cycles, PuDesign::dp().seed_dot_cycles(8));
        assert_eq!(trace.dpuu_cycles, (len - 1).div_ceil(8));
        assert_eq!(trace.dcu_cycles, len.div_ceil(8));
        assert_eq!(trace.puu_cycles, trace.dcu_cycles);
    }

    #[test]
    fn trace_and_descriptor_agree_on_cycles() {
        // The unified closed-form model: the functional PuTrace and the
        // descriptor ChunkWork must charge the SAME cycles for the same
        // work — diagonals and band tiles, DP and SP designs.  (They
        // used to disagree: PIPE_FILL=12 in the descriptor vs a
        // log2(lanes) tree depth in the trace.)
        check("pu-trace-vs-descriptor", 8, |rng: &mut Rng| {
            let n = rng.range(100, 500);
            let m = rng.range(4, 24);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let st = sliding_stats(&t, m);
            let nw = st.len();
            for design in [PuDesign::dp(), PuDesign::sp()] {
                let dp = PuDatapath::new(design, &t, &st);
                let mut pp = MatrixProfile::new_inf(nw, m, (m / 4).max(1));
                let width = rng.range(1, 9).min(nw / 2);
                let d0 = rng.range(1, nw - width);
                let tile = BandTile { d0, width };
                let (trace, work) = dp.run_band(tile, &mut pp);
                let chunk = ChunkWork {
                    cells: work.cells,
                    first_dots: width as u64,
                    m,
                };
                assert_eq!(
                    trace.cycles(),
                    chunk.cycles(&design),
                    "tile {tile:?}, lanes {}",
                    design.lanes
                );
            }
        });
    }

    #[test]
    fn band_tile_matches_per_diagonal_execution_bitwise() {
        // run_band over a tile == run_diagonal over each member diagonal
        let mut rng = Rng::new(34);
        let t: Vec<f64> = rng.gauss_vec(400);
        let m = 12;
        let st = sliding_stats(&t, m);
        let nw = st.len();
        let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
        let mut via_band = MatrixProfile::new_inf(nw, m, 3);
        let mut via_diag = MatrixProfile::new_inf(nw, m, 3);
        let tile = BandTile { d0: 7, width: 8 };
        let (_, wb) = dp.run_band(tile, &mut via_band);
        let mut wd = WorkStats::default();
        for d in tile.diagonals() {
            let (_, w) = dp.run_diagonal(d, &mut via_diag);
            wd.add(&w);
        }
        via_band.sqrt_in_place();
        via_diag.sqrt_in_place();
        assert!(via_band.max_abs_diff(&via_diag) == 0.0);
        assert_eq!(via_band.i, via_diag.i);
        assert_eq!(wb, wd);
    }
}
