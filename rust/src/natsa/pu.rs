//! NATSA processing-unit datapath: functional model + work descriptors.
//!
//! Paper Section 4.1 / Fig. 5: a PU is a control FSM around four shared-FPU
//! hardware components —
//!
//! * **DPU**  — dot product of the first window pair of a diagonal,
//! * **DCU**  — z-norm Euclidean distance (Eq. 1),
//! * **PUU**  — profile/index min-update,
//! * **DPUU** — incremental dot-product update (Eq. 2), replicated for
//!   vectorization and pipelined with DCU + PUU.
//!
//! This module gives that PU two faces:
//!
//! 1. [`PuDatapath`] — a *functional* cycle-by-cycle state machine that
//!    executes the six execution-flow steps of Section 4.1 literally
//!    (used by tests to pin the hardware semantics to SCRIMP's math, and
//!    by `examples/pu_trace.rs` to show the pipeline schedule);
//! 2. [`PuDesign`] + [`ChunkWork`] — the *descriptor* face: per-chunk
//!    cycle and DRAM-traffic accounting consumed by the timing/energy
//!    models in [`crate::sim::accel`] (gem5-Aladdin substitute).

use crate::mp::kernel;
use crate::mp::{MatrixProfile, WorkStats};
use crate::timeseries::WindowStats;
use crate::Real;

/// Static design parameters of one PU (paper Table 3, per-PU columns).
#[derive(Clone, Copy, Debug)]
pub struct PuDesign {
    /// Vector lanes: diagonal cells advanced per cycle at II=1.
    pub lanes: usize,
    /// FP multiplier / adder counts (Table 3).
    pub fp_mults: usize,
    pub fp_adds: usize,
    pub int_adds: usize,
    pub bitwise: usize,
    pub registers: usize,
    /// Private scratchpad for window size + configuration (Section 4.1).
    pub scratchpad_bytes: usize,
    /// Clock (GHz) — 1 GHz in the paper.
    pub freq_ghz: f64,
    /// HBM channel share per PU (GB/s) — 5 GB/s in Table 3.
    pub mem_bw_gbs: f64,
    /// Peak dynamic power (W) and area (mm², 45 nm) per Table 3.
    pub peak_power_w: f64,
    pub area_mm2: f64,
    /// Element width this design processes.
    pub elem_bytes: usize,
}

impl PuDesign {
    /// Double-precision PU (Table 3 column PU-DP).
    pub fn dp() -> Self {
        PuDesign {
            lanes: 8,
            fp_mults: 16,
            fp_adds: 14,
            int_adds: 16,
            bitwise: 2,
            registers: 108,
            scratchpad_bytes: 1024,
            freq_ghz: 1.0,
            mem_bw_gbs: 5.0,
            peak_power_w: 0.1,
            area_mm2: 1.62,
            elem_bytes: 8,
        }
    }

    /// Single-precision PU (Table 3 column PU-SP).
    pub fn sp() -> Self {
        PuDesign {
            lanes: 16,
            fp_mults: 64,
            fp_adds: 36,
            int_adds: 64,
            bitwise: 2,
            registers: 267,
            scratchpad_bytes: 1024,
            freq_ghz: 1.0,
            mem_bw_gbs: 5.0,
            peak_power_w: 0.08,
            area_mm2: 1.51,
            elem_bytes: 4,
        }
    }

    /// Pick the design matching an element type.
    pub fn for_dtype(dtype: &str) -> Self {
        match dtype {
            "f32" => Self::sp(),
            _ => Self::dp(),
        }
    }

    /// Peak cells/second of one PU (vector lanes at II=1).
    pub fn peak_cells_per_sec(&self) -> f64 {
        self.lanes as f64 * self.freq_ghz * 1e9
    }
}

/// One unit of PU work: a contiguous run of cells on one diagonal.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkWork {
    /// Cells computed (incremental, Eq. 2 path).
    pub cells: u64,
    /// Whether this chunk begins a diagonal (O(m) DPU dot product).
    pub first_dot: bool,
    /// Window length.
    pub m: usize,
}

impl ChunkWork {
    /// PU cycles: DPU startup (m / lanes, vectorized reduce) + pipeline
    /// fill + II=1 vector iterations over the cells.
    pub fn cycles(&self, d: &PuDesign) -> u64 {
        const PIPE_FILL: u64 = 12; // DPUU->DCU->PUU depth, Fig. 5
        let dot = if self.first_dot {
            (self.m as u64).div_ceil(d.lanes as u64) + PIPE_FILL
        } else {
            0
        };
        dot + self.cells.div_ceil(d.lanes as u64) + PIPE_FILL
    }

    /// DRAM bytes moved for this chunk.  Per cell the PU streams the two
    /// series points of Eq. 2, four statistics, and the two profile
    /// entries + indices it may update (Section 4.2 data mapping: profile
    /// vectors are PU-private but DRAM-resident; only `m`/config live in
    /// the 1 KB scratchpad).
    pub fn traffic_bytes(&self, d: &PuDesign) -> u64 {
        let e = d.elem_bytes as u64;
        let per_cell = 2 * e      // t[i+m-1], t[j+m-1] (t[i-1],t[j-1] reuse the stream)
            + 4 * e               // mu_i, mu_j, inv_msig_i, inv_msig_j
            + 2 * e               // P_i, P_j read
            + e;                  // amortized P/I write-back
        let dot = if self.first_dot { 2 * self.m as u64 * e } else { 0 };
        dot + self.cells * per_cell
    }

    /// FLOPs executed (Eq. 2: 4, Eq. 1: ~7, compares: 2 per cell).
    pub fn flops(&self) -> u64 {
        let dot = if self.first_dot { 2 * self.m as u64 } else { 0 };
        dot + self.cells * 13
    }
}

/// Pipeline stage occupancy, one entry per step of Section 4.1.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PuTrace {
    pub dpu_cycles: u64,
    pub dpuu_cycles: u64,
    pub dcu_cycles: u64,
    pub puu_cycles: u64,
}

/// Functional PU: executes one diagonal exactly as the Section 4.1 flow
/// describes, updating a (private) profile and producing a stage trace.
pub struct PuDatapath<'a, T> {
    pub design: PuDesign,
    t: &'a [T],
    st: &'a WindowStats<T>,
}

impl<'a, T: Real> PuDatapath<'a, T> {
    pub fn new(design: PuDesign, t: &'a [T], st: &'a WindowStats<T>) -> Self {
        PuDatapath { design, t, st }
    }

    /// Execute diagonal `d` against private profile `pp` following the six
    /// steps of Section 4.1.  Returns the stage trace and work stats.
    ///
    /// The arithmetic is [`kernel::compute_diagonal`] — the exact cell
    /// math every other engine runs, so a PU-fleet profile is
    /// bit-identical to a SCRIMP/STOMP one.  The stage occupancy is
    /// charged in closed form: one DPU burst (steps 1-3: seed dot,
    /// first distance, first update), then `lanes` cells per
    /// DPUU/DCU/PUU cycle at II=1 over the pipelined remainder
    /// (steps 4-6).
    ///
    /// PERF CONTRACT: `pp` accumulates **squared** distances; callers
    /// finalize with one [`MatrixProfile::sqrt_in_place`] after all
    /// diagonals merge.
    pub fn run_diagonal(&self, d: usize, pp: &mut MatrixProfile<T>) -> (PuTrace, WorkStats) {
        let m = self.st.m;
        let nw = self.st.len();
        let len = nw - d;
        let lanes = self.design.lanes as u64;
        let mut work = WorkStats::default();

        // Steps 1-6, functionally: the unified kernel (closed-form stats).
        kernel::compute_diagonal(self.t, self.st, d, pp, &mut work);

        // Stage occupancy in closed form.  Step 1 (DPU): vectorized tree
        // reduce over the m-point seed dot.  Steps 2-3 (DCU, PUU): one
        // cycle each for the seed cell.  Steps 4-6 (DPUU->DCU->PUU):
        // `lanes` cells per cycle at II=1 over the len-1 remaining cells.
        let vec_groups = (len as u64 - 1).div_ceil(lanes);
        let trace = PuTrace {
            dpu_cycles: (m as u64).div_ceil(lanes) + (lanes.trailing_zeros() as u64),
            dpuu_cycles: vec_groups,
            dcu_cycles: 1 + vec_groups,
            puu_cycles: 1 + vec_groups,
        };
        (trace, work)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mp::{scrimp, MpConfig};
    use crate::prop::{check, Rng};
    use crate::timeseries::sliding_stats;

    #[test]
    fn table3_per_pu_parameters() {
        let dp = PuDesign::dp();
        assert_eq!(dp.fp_mults, 16);
        assert_eq!(dp.fp_adds, 14);
        assert_eq!(dp.registers, 108);
        assert!((dp.mem_bw_gbs - 5.0).abs() < 1e-12);
        assert!((dp.peak_power_w - 0.1).abs() < 1e-12);
        let sp = PuDesign::sp();
        assert_eq!(sp.fp_mults, 64);
        assert_eq!(sp.registers, 267);
        assert!(sp.area_mm2 < dp.area_mm2);
    }

    #[test]
    fn datapath_matches_scrimp_per_diagonal() {
        check("pu-vs-scrimp", 10, |rng: &mut Rng| {
            let n = rng.range(80, 400);
            let m = rng.range(4, 20);
            if n < 4 * m {
                return;
            }
            let t: Vec<f64> = rng.gauss_vec(n);
            let st = sliding_stats(&t, m);
            let nw = st.len();
            let excl = (m / 4).max(1);
            let design = PuDesign::dp();
            let dp = PuDatapath::new(design, &t, &st);

            let mut via_pu = MatrixProfile::new_inf(nw, m, excl);
            let mut via_scrimp = MatrixProfile::new_inf(nw, m, excl);
            let mut w = WorkStats::default();
            for d in excl..nw {
                dp.run_diagonal(d, &mut via_pu);
                scrimp::compute_diagonal(&t, &st, d, &mut via_scrimp, &mut w);
            }
            // both paths run the unified kernel and defer the sqrt
            via_pu.sqrt_in_place();
            via_scrimp.sqrt_in_place();
            assert!(via_pu.max_abs_diff(&via_scrimp) == 0.0);
            assert_eq!(via_pu.i, via_scrimp.i);
        });
    }

    #[test]
    fn full_profile_through_datapath_matches_reference() {
        let mut rng = Rng::new(31);
        let t: Vec<f64> = rng.gauss_vec(300);
        let cfg = MpConfig::new(12);
        let st = sliding_stats(&t, 12);
        let nw = st.len();
        let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
        let mut mp = MatrixProfile::new_inf(nw, 12, cfg.exclusion());
        for d in cfg.exclusion()..nw {
            dp.run_diagonal(d, &mut mp);
        }
        mp.sqrt_in_place(); // the datapath defers the sqrt like every engine
        let want = scrimp::matrix_profile(&t, cfg).unwrap();
        assert!(mp.max_abs_diff(&want) == 0.0);
    }

    #[test]
    fn chunk_cycles_scale_with_lanes() {
        let w = ChunkWork { cells: 1024, first_dot: false, m: 128 };
        let dp_cycles = w.cycles(&PuDesign::dp());
        let sp_cycles = w.cycles(&PuDesign::sp());
        assert!(sp_cycles < dp_cycles);
        assert_eq!(w.cycles(&PuDesign::dp()), 1024 / 8 + 12);
    }

    #[test]
    fn first_dot_adds_startup() {
        let a = ChunkWork { cells: 100, first_dot: false, m: 256 };
        let b = ChunkWork { cells: 100, first_dot: true, m: 256 };
        let d = PuDesign::dp();
        assert!(b.cycles(&d) > a.cycles(&d));
        assert!(b.traffic_bytes(&d) > a.traffic_bytes(&d));
    }

    #[test]
    fn sp_traffic_half_of_dp() {
        let w = ChunkWork { cells: 1000, first_dot: false, m: 64 };
        assert_eq!(
            w.traffic_bytes(&PuDesign::dp()),
            2 * w.traffic_bytes(&PuDesign::sp())
        );
    }

    #[test]
    fn trace_pipeline_counts() {
        let mut rng = Rng::new(33);
        let t: Vec<f64> = rng.gauss_vec(200);
        let st = sliding_stats(&t, 8);
        let dp = PuDatapath::new(PuDesign::dp(), &t, &st);
        let nw = st.len();
        let mut pp = MatrixProfile::new_inf(nw, 8, 2);
        let (trace, work) = dp.run_diagonal(10, &mut pp);
        // one DPU burst, then ceil((len-1)/lanes) vector groups
        let len = (nw - 10) as u64;
        assert_eq!(trace.dpuu_cycles, (len - 1).div_ceil(8));
        assert_eq!(trace.dcu_cycles, 1 + trace.dpuu_cycles);
        assert_eq!(work.cells, len);
    }
}
