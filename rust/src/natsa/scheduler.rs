//! NATSA's workload partitioning scheme (paper Section 4.2).
//!
//! Diagonals of the distance matrix have different lengths (diagonal `d`
//! has `nw - d` cells), so a naive split load-imbalances the PUs.  NATSA
//! pairs the *k-th shortest remaining* diagonal with the *k-th longest*:
//! every pair then sums to exactly
//!
//! ```text
//! (nw - first) + (nw - last) = nw - excl + 1   cells
//! ```
//!
//! (the paper states this as `(n - m + 1) - m/4`, the main-diagonal-length
//! minus the exclusion zone).  Pairs are dealt round-robin to PUs, so
//! every PU receives the same cell count to within one pair — *static*
//! balance, independent of the data, preserving the anytime property
//! because each PU's list can still be visited in any order.

use crate::prop::Rng;

/// A pair of diagonals with complementary lengths (the second is `None`
/// for the unpaired middle diagonal when the count is odd).
pub type DiagPair = (usize, Option<usize>);

/// The output of the partitioning scheme.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Diagonal indices assigned to each PU, in assignment order
    /// (alternating long/short so progress is spatially uniform).
    pub per_pu: Vec<Vec<usize>>,
    /// The balanced pairs, in dealing order.
    pub pairs: Vec<DiagPair>,
    /// Window count and exclusion zone used to build the schedule.
    pub nw: usize,
    pub excl: usize,
}

impl Schedule {
    /// Cells of work assigned to PU `k`.
    pub fn load(&self, k: usize) -> u64 {
        self.per_pu[k]
            .iter()
            .map(|&d| (self.nw - d) as u64)
            .sum()
    }

    /// max/min load ratio over the PUs that received work (1.0 = perfectly
    /// balanced).  PUs left idle because pairs ran out (more PUs than
    /// pairs) are *excluded* — an idle PU is a capacity question, not a
    /// balance one, and folding its zero load in used to pin the metric at
    /// infinity exactly when balance mattered.  Idle capacity is reported
    /// separately by [`Self::idle_pus`].
    pub fn imbalance(&self) -> f64 {
        let mut max = 0u64;
        let mut min = u64::MAX;
        for k in 0..self.per_pu.len() {
            let l = self.load(k);
            if l > 0 {
                max = max.max(l);
                min = min.min(l);
            }
        }
        if max == 0 {
            1.0 // no work at all: vacuously balanced
        } else {
            max as f64 / min as f64
        }
    }

    /// PUs that received no diagonals (happens when PUs outnumber pairs).
    pub fn idle_pus(&self) -> usize {
        self.per_pu.iter().filter(|l| l.is_empty()).count()
    }

    /// Shuffle each PU's list in place (anytime mode, Section 4.2 way 1).
    pub fn randomize(&mut self, seed: u64) {
        for (k, list) in self.per_pu.iter_mut().enumerate() {
            Rng::new(seed ^ ((k as u64) << 32)).shuffle(list);
        }
    }

    /// Sort each PU's list ascending (sequential mode, way 2 — locality).
    pub fn sequentialize(&mut self) {
        for list in &mut self.per_pu {
            list.sort_unstable();
        }
    }
}

/// Build the balanced diagonal-pair schedule for `pus` processing units
/// over windows `nw` with exclusion radius `excl`.
///
/// Diagonals `excl ..= nw-1` are paired outside-in; pairs are dealt
/// round-robin.  Panics if there is no admissible diagonal.
pub fn schedule(nw: usize, excl: usize, pus: usize) -> Schedule {
    assert!(pus >= 1, "need at least one PU");
    assert!(nw > excl, "no admissible diagonals (nw={nw}, excl={excl})");

    let mut lo = excl;
    let mut hi = nw - 1;
    let mut pairs: Vec<DiagPair> = Vec::with_capacity((nw - excl).div_ceil(2));
    while lo < hi {
        pairs.push((lo, Some(hi)));
        lo += 1;
        hi -= 1;
    }
    if lo == hi {
        pairs.push((lo, None));
    }

    let mut per_pu = vec![Vec::new(); pus];
    for (k, (a, b)) in pairs.iter().enumerate() {
        let list = &mut per_pu[k % pus];
        list.push(*a);
        if let Some(b) = b {
            list.push(*b);
        }
    }
    Schedule { per_pu, pairs, nw, excl }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    #[test]
    fn paper_example_two_pus() {
        // Fig. 6: n=13, m=4 -> nw = 10 windows, exclusion = 1 extra
        // diagonal beyond the main one => diagonals 2..=9 are computed.
        // (paper indexes columns from 1; we use 0-based diagonals)
        let s = schedule(10, 2, 2);
        // each pair must sum to (nw - excl + 1) = 9 cells
        for (a, b) in &s.pairs {
            if let Some(b) = b {
                assert_eq!((s.nw - a) + (s.nw - b), 9);
            }
        }
        // PU0 gets pairs 0 and 2; PU1 gets pairs 1 and 3
        assert_eq!(s.per_pu[0], vec![2, 9, 4, 7]);
        assert_eq!(s.per_pu[1], vec![3, 8, 5, 6]);
        assert_eq!(s.load(0), s.load(1));
    }

    #[test]
    fn pairs_sum_constant() {
        let s = schedule(1000, 16, 48);
        for (a, b) in &s.pairs {
            if let Some(b) = b {
                assert_eq!((s.nw - a) + (s.nw - b), s.nw - s.excl + 1);
            }
        }
    }

    #[test]
    fn prop_coverage_exactly_once() {
        check("schedule-coverage", 30, |rng| {
            let nw = rng.range(10, 3000);
            let excl = rng.range(1, (nw / 2).max(2));
            let pus = rng.range(1, 65);
            let s = schedule(nw, excl, pus);
            let mut all: Vec<usize> = s.per_pu.concat();
            all.sort_unstable();
            assert_eq!(all, (excl..nw).collect::<Vec<_>>());
        });
    }

    #[test]
    fn prop_near_perfect_balance() {
        check("schedule-balance", 30, |rng| {
            let nw = rng.range(500, 5000);
            let excl = rng.range(1, 32);
            let pus = rng.range(2, 65);
            let s = schedule(nw, excl, pus);
            let total: u64 = (0..pus).map(|k| s.load(k)).sum();
            assert_eq!(total, crate::mp::total_cells(nw, excl));
            // every PU is within one pair's worth of cells of the mean
            let pair_cells = (nw - excl + 1) as f64;
            let mean = total as f64 / pus as f64;
            for k in 0..pus {
                let dev = (s.load(k) as f64 - mean).abs();
                assert!(
                    dev <= pair_cells,
                    "PU{k} load {} vs mean {mean} (pair {pair_cells})",
                    s.load(k)
                );
            }
        });
    }

    #[test]
    fn prop_randomize_is_permutation() {
        check("schedule-randomize", 10, |rng| {
            let nw = rng.range(50, 800);
            let excl = rng.range(1, 8);
            let mut s = schedule(nw, excl, 7);
            let before: Vec<Vec<usize>> = s
                .per_pu
                .iter()
                .map(|l| {
                    let mut v = l.clone();
                    v.sort_unstable();
                    v
                })
                .collect();
            s.randomize(42);
            for (k, list) in s.per_pu.iter().enumerate() {
                let mut v = list.clone();
                v.sort_unstable();
                assert_eq!(v, before[k]);
            }
        });
    }

    #[test]
    fn sequentialize_sorts() {
        let mut s = schedule(100, 4, 3);
        s.sequentialize();
        for list in &s.per_pu {
            assert!(list.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn more_pus_than_pairs_leaves_idle_pus() {
        let s = schedule(8, 4, 16); // diagonals 4..=7 -> 2 pairs
        assert_eq!(s.pairs.len(), 2);
        let nonempty = s.per_pu.iter().filter(|l| !l.is_empty()).count();
        assert_eq!(nonempty, 2);
        assert_eq!(s.idle_pus(), 14);
    }

    #[test]
    fn imbalance_is_finite_with_idle_pus() {
        // regression: idle PUs (min load 0) used to pin imbalance at
        // infinity — the metric must rate the *working* PUs instead
        let s = schedule(8, 4, 16); // 2 pairs, each (8-4)+(8-7) = 5 cells
        assert_eq!(s.imbalance(), 1.0);
        assert!(s.imbalance().is_finite());
        // a single-PU schedule is trivially balanced, never infinite
        let one = schedule(100, 4, 1);
        assert_eq!(one.imbalance(), 1.0);
        assert_eq!(one.idle_pus(), 0);
    }

    #[test]
    #[should_panic(expected = "no admissible diagonals")]
    fn degenerate_panics() {
        schedule(4, 4, 2);
    }
}
