//! NATSA's workload partitioning scheme (paper Section 4.2), at two
//! granularities.
//!
//! Diagonals of the distance matrix have different lengths (diagonal `d`
//! has `nw - d` cells), so a naive split load-imbalances the PUs.  NATSA
//! pairs the *k-th shortest remaining* diagonal with the *k-th longest*:
//! every pair then sums to exactly
//!
//! ```text
//! (nw - first) + (nw - last) = nw - excl + 1   cells
//! ```
//!
//! (the paper states this as `(n - m + 1) - m/4`, the main-diagonal-length
//! minus the exclusion zone).  Pairs are dealt round-robin to PUs, so
//! every PU receives the same cell count to within one pair — *static*
//! balance, independent of the data, preserving the anytime property
//! because each PU's list can still be visited in any order.
//! [`schedule`] builds that classic per-diagonal scheme.
//!
//! ## Band-granular scheduling
//!
//! The unified kernel's band path ([`crate::mp::kernel::compute_band_n`])
//! is ~2x faster per cell than per-diagonal walking, but it needs
//! *adjacent* diagonals — which round-robin pair dealing never produces
//! (a PU's diagonals sit `pus` apart).  [`schedule_banded`] therefore
//! deals [`BandTile`]s — runs of up to [`BAND`] adjacent diagonals — with
//! the same outside-in idea lifted to tile granularity:
//!
//! 1. the admissible range is cut into tiles of `width` adjacent
//!    diagonals (`width` shrinks below [`BAND`] on small workloads so
//!    banding never costs balance; at width 1 the scheme degenerates to
//!    the classic one);
//! 2. a *long-head* tile is paired with the mirrored *short-tail* tile.
//!    Tile cell-count is linear in its first diagonal, so full-width
//!    outside-in pairs have **exactly** equal sums;
//! 3. only whole `pus`-sized rounds of coarse pairs are dealt round-robin
//!    (the coarse part is therefore perfectly balanced), and the leftover
//!    middle tiles plus the ragged tail are re-paired outside-in at
//!    single-diagonal granularity — the classic scheme's quantum — so the
//!    residual deviation stays at one diagonal-pair, not one tile-pair.
//!
//! The result keeps the paper's static balance and the anytime property
//! (tile lists may be visited in any order; a tile is the interruption
//! quantum) while putting >95% of cells on the multi-lane band path for
//! fleet-sized workloads.

use crate::mp::kernel::BAND;
use crate::prop::Rng;

/// A pair of diagonals with complementary lengths (the second is `None`
/// for the unpaired middle diagonal when the count is odd).
pub type DiagPair = (usize, Option<usize>);

/// The output of the partitioning scheme.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Diagonal indices assigned to each PU, in assignment order
    /// (alternating long/short so progress is spatially uniform).
    pub per_pu: Vec<Vec<usize>>,
    /// The balanced pairs, in dealing order.
    pub pairs: Vec<DiagPair>,
    /// Window count and exclusion zone used to build the schedule.
    pub nw: usize,
    pub excl: usize,
}

/// Shared balance metric: max/min load ratio over the PUs that received
/// work (1.0 = perfectly balanced).  PUs left idle because pairs ran out
/// (more PUs than pairs) are *excluded* — an idle PU is a capacity
/// question, not a balance one, and folding its zero load in used to pin
/// the metric at infinity exactly when balance mattered.
fn imbalance_over(loads: impl Iterator<Item = u64>) -> f64 {
    let mut max = 0u64;
    let mut min = u64::MAX;
    for l in loads {
        if l > 0 {
            max = max.max(l);
            min = min.min(l);
        }
    }
    if max == 0 {
        1.0 // no work at all: vacuously balanced
    } else {
        max as f64 / min as f64
    }
}

/// Shared per-PU shuffle (anytime mode, Section 4.2 way 1): one
/// deterministic stream per PU so work-unit granularity doesn't change
/// the seed mixing.
fn randomize_lists<T>(lists: &mut [Vec<T>], seed: u64) {
    for (k, list) in lists.iter_mut().enumerate() {
        Rng::new(seed ^ ((k as u64) << 32)).shuffle(list);
    }
}

impl Schedule {
    /// Cells of work assigned to PU `k`.
    pub fn load(&self, k: usize) -> u64 {
        self.per_pu[k]
            .iter()
            .map(|&d| (self.nw - d) as u64)
            .sum()
    }

    /// max/min load ratio over the PUs that received work (see
    /// [`imbalance_over`]; idle capacity is reported separately by
    /// [`Self::idle_pus`]).
    pub fn imbalance(&self) -> f64 {
        imbalance_over((0..self.per_pu.len()).map(|k| self.load(k)))
    }

    /// PUs that received no diagonals (happens when PUs outnumber pairs).
    pub fn idle_pus(&self) -> usize {
        self.per_pu.iter().filter(|l| l.is_empty()).count()
    }

    /// Shuffle each PU's list in place (anytime mode, Section 4.2 way 1).
    pub fn randomize(&mut self, seed: u64) {
        randomize_lists(&mut self.per_pu, seed);
    }

    /// Sort each PU's list ascending (sequential mode, way 2 — locality).
    pub fn sequentialize(&mut self) {
        for list in &mut self.per_pu {
            list.sort_unstable();
        }
    }
}

/// Build the balanced diagonal-pair schedule for `pus` processing units
/// over windows `nw` with exclusion radius `excl`.
///
/// Diagonals `excl ..= nw-1` are paired outside-in; pairs are dealt
/// round-robin.  Panics if there is no admissible diagonal.
pub fn schedule(nw: usize, excl: usize, pus: usize) -> Schedule {
    assert!(pus >= 1, "need at least one PU");
    assert!(nw > excl, "no admissible diagonals (nw={nw}, excl={excl})");

    let mut lo = excl;
    let mut hi = nw - 1;
    let mut pairs: Vec<DiagPair> = Vec::with_capacity((nw - excl).div_ceil(2));
    while lo < hi {
        pairs.push((lo, Some(hi)));
        lo += 1;
        hi -= 1;
    }
    if lo == hi {
        pairs.push((lo, None));
    }

    let mut per_pu = vec![Vec::new(); pus];
    for (k, (a, b)) in pairs.iter().enumerate() {
        let list = &mut per_pu[k % pus];
        list.push(*a);
        if let Some(b) = b {
            list.push(*b);
        }
    }
    Schedule { per_pu, pairs, nw, excl }
}

/// A tile of `width` adjacent diagonals `d0..d0+width` — the work unit
/// the band-granular scheduler deals to PUs, executed in one call to
/// [`crate::mp::kernel::compute_band_n`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BandTile {
    /// First diagonal of the tile.
    pub d0: usize,
    /// Adjacent diagonals in the tile (`1..=BAND`).
    pub width: usize,
}

impl BandTile {
    /// The diagonals this tile covers.
    pub fn diagonals(&self) -> std::ops::Range<usize> {
        self.d0..self.d0 + self.width
    }

    /// Cells of work in this tile for a profile of `nw` windows.
    pub fn cells(&self, nw: usize) -> u64 {
        self.diagonals().map(|d| (nw - d) as u64).sum()
    }
}

/// A pair of band tiles with complementary cell counts (the second is
/// `None` for an unpaired middle tile when the count is odd).
pub type TilePair = (BandTile, Option<BandTile>);

/// The output of band-granular partitioning ([`schedule_banded`]).
#[derive(Clone, Debug)]
pub struct BandedSchedule {
    /// Band tiles assigned to each PU, in assignment order
    /// (alternating long/short so progress is spatially uniform).
    pub per_pu: Vec<Vec<BandTile>>,
    /// The balanced tile pairs, in dealing order (coarse pairs first,
    /// then the single-diagonal fine tail).
    pub pairs: Vec<TilePair>,
    /// Window count and exclusion zone used to build the schedule.
    pub nw: usize,
    pub excl: usize,
    /// Coarse tile width chosen for this workload (`1..=BAND`).
    pub width: usize,
}

impl BandedSchedule {
    /// Cells of work assigned to PU `k`.
    pub fn load(&self, k: usize) -> u64 {
        self.per_pu[k].iter().map(|t| t.cells(self.nw)).sum()
    }

    /// Diagonals assigned to PU `k` (each costs one O(m) seed dot).
    pub fn diagonals_assigned(&self, k: usize) -> u64 {
        self.per_pu[k].iter().map(|t| t.width as u64).sum()
    }

    /// max/min load ratio over the PUs that received work (see
    /// [`imbalance_over`]; idle PUs are excluded and counted by
    /// [`Self::idle_pus`]).
    pub fn imbalance(&self) -> f64 {
        imbalance_over((0..self.per_pu.len()).map(|k| self.load(k)))
    }

    /// PUs that received no tiles (happens when PUs outnumber pairs).
    pub fn idle_pus(&self) -> usize {
        self.per_pu.iter().filter(|l| l.is_empty()).count()
    }

    /// Shuffle each PU's tile list in place (anytime mode, Section 4.2
    /// way 1 — the tile is the interruption quantum).
    pub fn randomize(&mut self, seed: u64) {
        randomize_lists(&mut self.per_pu, seed);
    }

    /// Sort each PU's tile list by first diagonal (sequential mode, way
    /// 2 — locality).
    pub fn sequentialize(&mut self) {
        for list in &mut self.per_pu {
            list.sort_unstable_by_key(|t| t.d0);
        }
    }
}

/// Build the band-granular balanced schedule for `pus` processing units
/// over windows `nw` with exclusion radius `excl` (see the module docs
/// for the scheme).  Panics if there is no admissible diagonal.
pub fn schedule_banded(nw: usize, excl: usize, pus: usize) -> BandedSchedule {
    assert!(pus >= 1, "need at least one PU");
    assert!(nw > excl, "no admissible diagonals (nw={nw}, excl={excl})");

    let d_total = nw - excl;
    // Coarse width: BAND when every PU can receive whole coarse pairs,
    // narrower on small workloads (width 1 == the classic scheme).
    let width = (d_total / (2 * pus)).clamp(1, BAND);
    let full_tiles = d_total / width;
    // Keep only whole pus-sized rounds of coarse pairs: full-width
    // outside-in pairs have exactly equal sums (tile cells are linear in
    // d0), so round-robin dealing leaves the coarse part perfectly
    // balanced.
    let coarse_pairs = (full_tiles / 2) / pus * pus;
    let mut pairs: Vec<TilePair> = Vec::with_capacity(coarse_pairs + d_total.div_ceil(2));
    for j in 0..coarse_pairs {
        let head = BandTile { d0: excl + j * width, width };
        let tail = BandTile { d0: excl + (full_tiles - 1 - j) * width, width };
        pairs.push((head, Some(tail)));
    }

    // Fine tail: the undealt middle tiles plus the ragged remainder, as
    // single-diagonal tiles paired outside-in (the classic quantum), so
    // pair-count quantization costs one diagonal-pair — not one
    // tile-pair — of deviation.
    let mut fine: Vec<usize> =
        (excl + coarse_pairs * width..excl + (full_tiles - coarse_pairs) * width).collect();
    fine.extend(excl + full_tiles * width..nw);
    let solo = |d: usize| BandTile { d0: d, width: 1 };
    let mut lo = 0usize;
    let mut hi = fine.len();
    while lo + 1 < hi {
        pairs.push((solo(fine[lo]), Some(solo(fine[hi - 1]))));
        lo += 1;
        hi -= 1;
    }
    if lo + 1 == hi {
        pairs.push((solo(fine[lo]), None));
    }

    let mut per_pu = vec![Vec::new(); pus];
    for (k, (a, b)) in pairs.iter().enumerate() {
        let list = &mut per_pu[k % pus];
        list.push(*a);
        if let Some(b) = b {
            list.push(*b);
        }
    }
    BandedSchedule { per_pu, pairs, nw, excl, width }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::check;

    /// The shared invariants both partitioning schemes must satisfy,
    /// phrased over (per-PU diagonal lists, per-pair diagonal lists):
    /// every admissible diagonal exactly once, total load preserved, and
    /// every working PU within one dealing quantum (the largest pair) of
    /// the mean.  Asserting these — rather than one dealing order —
    /// keeps the tests meaningful for the legacy and banded schedules
    /// alike.
    fn assert_schedule_invariants(
        name: &str,
        nw: usize,
        excl: usize,
        per_pu_diags: &[Vec<usize>],
        pair_loads: &[u64],
    ) {
        let mut all: Vec<usize> = per_pu_diags.concat();
        all.sort_unstable();
        assert_eq!(all, (excl..nw).collect::<Vec<_>>(), "{name}: coverage");

        let load = |l: &Vec<usize>| l.iter().map(|&d| (nw - d) as u64).sum::<u64>();
        let loads: Vec<u64> = per_pu_diags.iter().map(load).collect();
        let total: u64 = loads.iter().sum();
        assert_eq!(total, crate::mp::total_cells(nw, excl), "{name}: total");

        let max_pair = *pair_loads.iter().max().unwrap() as f64;
        let mean = total as f64 / loads.len() as f64;
        for (k, &l) in loads.iter().enumerate() {
            if l == 0 {
                continue; // idle PU (more PUs than pairs): capacity, not balance
            }
            let dev = (l as f64 - mean).abs();
            assert!(
                dev <= max_pair,
                "{name}: PU{k} load {l} vs mean {mean} (max pair {max_pair})"
            );
        }
    }

    fn legacy_diags(s: &Schedule) -> Vec<Vec<usize>> {
        s.per_pu.clone()
    }

    fn legacy_pair_loads(s: &Schedule) -> Vec<u64> {
        s.pairs
            .iter()
            .map(|(a, b)| {
                (s.nw - a) as u64 + b.map_or(0, |b| (s.nw - b) as u64)
            })
            .collect()
    }

    fn banded_diags(s: &BandedSchedule) -> Vec<Vec<usize>> {
        s.per_pu
            .iter()
            .map(|tiles| tiles.iter().flat_map(|t| t.diagonals()).collect())
            .collect()
    }

    fn banded_pair_loads(s: &BandedSchedule) -> Vec<u64> {
        s.pairs
            .iter()
            .map(|(a, b)| a.cells(s.nw) + b.map_or(0, |b| b.cells(s.nw)))
            .collect()
    }

    #[test]
    fn paper_example_two_pus() {
        // Fig. 6: n=13, m=4 -> nw = 10 windows, exclusion = 1 extra
        // diagonal beyond the main one => diagonals 2..=9 are computed.
        // (paper indexes columns from 1; we use 0-based diagonals)
        let s = schedule(10, 2, 2);
        // each legacy pair must sum to (nw - excl + 1) = 9 cells
        for &l in &legacy_pair_loads(&s) {
            assert_eq!(l, 9);
        }
        assert_schedule_invariants("legacy", 10, 2, &legacy_diags(&s), &legacy_pair_loads(&s));
        assert_eq!(s.load(0), s.load(1));

        // the banded schedule keeps the same invariants at tile
        // granularity (here width 2: 8 diagonals over 2 PUs), including
        // exactly equal loads — full-width outside-in tile pairs have
        // constant sums just like the paper's diagonal pairs
        let b = schedule_banded(10, 2, 2);
        assert_eq!(b.width, 2);
        for &l in &banded_pair_loads(&b) {
            assert_eq!(l, 18); // two diagonal-pairs' worth per tile pair
        }
        assert_schedule_invariants("banded", 10, 2, &banded_diags(&b), &banded_pair_loads(&b));
        assert_eq!(b.load(0), b.load(1));
    }

    #[test]
    fn pairs_sum_constant() {
        let s = schedule(1000, 16, 48);
        for (a, b) in &s.pairs {
            if let Some(b) = b {
                assert_eq!((s.nw - a) + (s.nw - b), s.nw - s.excl + 1);
            }
        }
        // banded: every COARSE pair (both tiles full width) sums to the
        // same constant — the pairing invariant lifted to tiles
        let b = schedule_banded(1000, 16, 48);
        let coarse: Vec<u64> = b
            .pairs
            .iter()
            .filter(|(x, y)| {
                x.width == b.width && y.is_some_and(|y| y.width == b.width)
            })
            .map(|(x, y)| x.cells(b.nw) + y.unwrap().cells(b.nw))
            .collect();
        assert!(!coarse.is_empty());
        assert!(coarse.iter().all(|&c| c == coarse[0]), "{coarse:?}");
    }

    #[test]
    fn prop_coverage_exactly_once() {
        check("schedule-coverage", 30, |rng| {
            let nw = rng.range(10, 3000);
            let excl = rng.range(1, (nw / 2).max(2));
            let pus = rng.range(1, 65);
            let s = schedule(nw, excl, pus);
            let mut all: Vec<usize> = s.per_pu.concat();
            all.sort_unstable();
            assert_eq!(all, (excl..nw).collect::<Vec<_>>());
            let b = schedule_banded(nw, excl, pus);
            let mut all: Vec<usize> = banded_diags(&b).concat();
            all.sort_unstable();
            assert_eq!(all, (excl..nw).collect::<Vec<_>>(), "banded nw={nw} excl={excl} pus={pus}");
            assert!(b.per_pu.iter().flatten().all(|t| (1..=BAND).contains(&t.width)));
        });
    }

    #[test]
    fn prop_near_perfect_balance() {
        check("schedule-balance", 30, |rng| {
            let nw = rng.range(500, 5000);
            let excl = rng.range(1, 32);
            let pus = rng.range(2, 65);
            let s = schedule(nw, excl, pus);
            assert_schedule_invariants(
                "legacy",
                nw,
                excl,
                &legacy_diags(&s),
                &legacy_pair_loads(&s),
            );
            let b = schedule_banded(nw, excl, pus);
            assert_schedule_invariants(
                "banded",
                nw,
                excl,
                &banded_diags(&b),
                &banded_pair_loads(&b),
            );
            // the deviation bound above is per dealing quantum; the
            // RELATIVE imbalance must stay near-perfect for both schemes
            // on fleet-sized workloads
            if crate::mp::total_cells(nw, excl) / pus as u64 > 20 * (nw as u64) {
                assert!(s.imbalance() < 1.10, "legacy {}", s.imbalance());
                assert!(b.imbalance() < 1.10, "banded {}", b.imbalance());
            }
        });
    }

    #[test]
    fn prop_randomize_is_permutation() {
        check("schedule-randomize", 10, |rng| {
            let nw = rng.range(50, 800);
            let excl = rng.range(1, 8);
            let mut s = schedule(nw, excl, 7);
            let before: Vec<Vec<usize>> = s
                .per_pu
                .iter()
                .map(|l| {
                    let mut v = l.clone();
                    v.sort_unstable();
                    v
                })
                .collect();
            s.randomize(42);
            for (k, list) in s.per_pu.iter().enumerate() {
                let mut v = list.clone();
                v.sort_unstable();
                assert_eq!(v, before[k]);
            }
        });
    }

    #[test]
    fn sequentialize_sorts() {
        let mut s = schedule(100, 4, 3);
        s.sequentialize();
        for list in &s.per_pu {
            assert!(list.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn more_pus_than_pairs_leaves_idle_pus() {
        let s = schedule(8, 4, 16); // diagonals 4..=7 -> 2 pairs
        assert_eq!(s.pairs.len(), 2);
        let nonempty = s.per_pu.iter().filter(|l| !l.is_empty()).count();
        assert_eq!(nonempty, 2);
        assert_eq!(s.idle_pus(), 14);
    }

    #[test]
    fn imbalance_is_finite_with_idle_pus() {
        // regression: idle PUs (min load 0) used to pin imbalance at
        // infinity — the metric must rate the *working* PUs instead
        let s = schedule(8, 4, 16); // 2 pairs, each (8-4)+(8-7) = 5 cells
        assert_eq!(s.imbalance(), 1.0);
        assert!(s.imbalance().is_finite());
        // a single-PU schedule is trivially balanced, never infinite
        let one = schedule(100, 4, 1);
        assert_eq!(one.imbalance(), 1.0);
        assert_eq!(one.idle_pus(), 0);
    }

    #[test]
    #[should_panic(expected = "no admissible diagonals")]
    fn degenerate_panics() {
        schedule(4, 4, 2);
    }

    #[test]
    #[should_panic(expected = "no admissible diagonals")]
    fn banded_degenerate_panics() {
        schedule_banded(4, 4, 2);
    }

    #[test]
    fn banded_width_adapts_to_workload() {
        // fleet-sized work: full BAND tiles; small work: narrower, down
        // to the classic width-1 scheme, so banding never costs balance
        assert_eq!(schedule_banded(4000, 4, 8).width, BAND);
        assert_eq!(schedule_banded(100, 4, 1).width, BAND);
        assert_eq!(schedule_banded(10, 2, 2).width, 2);
        assert_eq!(schedule_banded(8, 4, 16).width, 1);
        // a single-PU sweep is tiled almost entirely at full width
        let s = schedule_banded(100, 4, 1);
        assert!(s.per_pu[0].iter().all(|t| t.width == BAND));
    }

    #[test]
    fn banded_more_pus_than_pairs_leaves_idle_pus() {
        let s = schedule_banded(8, 4, 16); // 4 diagonals -> 2 width-1 pairs
        assert_eq!(s.pairs.len(), 2);
        assert_eq!(s.idle_pus(), 14);
        assert!(s.imbalance().is_finite());
        assert_eq!(s.imbalance(), 1.0);
    }

    #[test]
    fn banded_randomize_permutes_and_sequentialize_sorts() {
        let mut s = schedule_banded(2000, 8, 7);
        let mut before: Vec<Vec<BandTile>> = s.per_pu.clone();
        for l in &mut before {
            l.sort_unstable_by_key(|t| t.d0);
        }
        s.randomize(42);
        for (k, list) in s.per_pu.iter().enumerate() {
            let mut v = list.clone();
            v.sort_unstable_by_key(|t| t.d0);
            assert_eq!(v, before[k], "randomize must permute, not alter");
        }
        s.sequentialize();
        for list in &s.per_pu {
            assert!(list.windows(2).all(|w| w[0].d0 < w[1].d0));
        }
    }

    #[test]
    fn banded_loads_match_diagonal_expansion() {
        // load()/diagonals_assigned() over tile cell-counts must agree
        // with brute expansion to diagonals
        let s = schedule_banded(3000, 16, 48);
        for k in 0..48 {
            let diags: Vec<usize> = s.per_pu[k].iter().flat_map(|t| t.diagonals()).collect();
            let want: u64 = diags.iter().map(|&d| (s.nw - d) as u64).sum();
            assert_eq!(s.load(k), want);
            assert_eq!(s.diagonals_assigned(k), diags.len() as u64);
        }
    }
}
