//! # NATSA — Near-Data Processing Accelerator for Time Series Analysis
//!
//! Full-system reproduction of *NATSA: A Near-Data Processing Accelerator
//! for Time Series Analysis* (Fernandez et al., ICCD 2020) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the coordinator: NATSA's workload
//!   partitioning at diagonal and band-tile granularity
//!   ([`natsa::scheduler`] — the fleet deals balanced pairs of
//!   adjacent-diagonal tiles so every PU rides the SIMD band kernel,
//!   and a tile is the anytime interruption quantum), the PU fleet and
//!   its functional datapath ([`natsa::pu`]), the host API of
//!   Algorithm 2 ([`natsa`]), software baselines ([`mp`]), the
//!   evaluation substrates the paper ran on
//!   ZSim/gem5/Ramulator/McPAT/Aladdin ([`sim`]), and the request-path
//!   runtime that executes AOT-compiled kernels through xla/PJRT
//!   ([`runtime`], [`coordinator`]).
//! * **Layer 2 (python/compile/model.py, build-time only)** — the JAX
//!   compute graphs the host offloads, lowered once to HLO text in
//!   `artifacts/`.
//! * **Layer 1 (python/compile/kernels/, build-time only)** — Pallas
//!   kernels implementing the PU pipeline (DPU → DPUU → DCU → PUU).
//!
//! Python never runs on the request path: `make artifacts` lowers the
//! kernels once and the rust binary is self-contained afterwards.
//!
//! ## Quick start
//!
//! ```no_run
//! use natsa::natsa::{NatsaConfig, NatsaEngine};
//! use natsa::timeseries::generator::{self, Pattern};
//!
//! let t = generator::generate::<f64>(Pattern::SineWithAnomaly, 4096, 7);
//! let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
//! let out = engine.compute(&t, 64).unwrap();
//! let (pos, _) = out.profile.discord().unwrap();
//! println!("strongest anomaly near index {pos}");
//! ```
//!
//! ## Streaming quick start
//!
//! The batch API above sees the whole series at once; continuous
//! monitoring (ECG feeds, sensor streams) instead appends samples
//! forever.  [`mp::stampi`] maintains the **exact** matrix profile under
//! `append(sample)` at O(n) per sample (the STAMPI row update), with an
//! optional bounded history for O(memory)-constrained monitors.  The
//! row update runs on the unified kernel's row entry point
//! ([`mp::kernel::compute_row_n`]): appends are width-1 tiles, batched
//! appends ([`mp::stampi::Stampi::extend`], the service's append jobs)
//! block up to `BAND` samples into one multi-row SIMD tile, and the
//! live profile keeps the kernel's squared-distance representation with
//! one deferred sqrt per snapshot:
//!
//! ```no_run
//! use natsa::natsa::{NatsaConfig, NatsaEngine};
//! use natsa::timeseries::generator::{self, Pattern};
//!
//! let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
//! let mut session = engine.open_stream(64).unwrap();
//! let feed = generator::generate::<f64>(Pattern::EcgLike, 8192, 5);
//! for x in feed {
//!     session.append(x); // O(n) per sample, profile always exact
//!     if let Some((w, d)) = session.profile().discord() {
//!         if d > 6.0 {
//!             println!("anomaly developing at window {w} (d={d:.2})");
//!         }
//!     }
//! }
//! ```
//!
//! The same session runs behind the **sharded** multi-client service
//! ([`coordinator::service::AnalysisService::submit_stream`] /
//! `append_stream` / `snapshot_stream` — each stream pinned to one
//! engine shard so pipelined appends never head-of-line block the
//! fleet), optionally with a per-shard write-ahead log
//! ([`coordinator::wal`], enabled by
//! `ServiceConfig::with_wal(dir)`) that replays every open session
//! bit-identically after a crash or restart.  Shard workers **coalesce
//! across streams**: concurrent single-sample appends with compatible
//! configuration are drained from the queue together and fused into one
//! shared multi-lane row tile ([`mp::kernel::compute_row_group`];
//! per-stream order and bit-identity preserved, widths observable in
//! the `coalesce_width` metric), so the no-batching steady state still
//! rides the blocked path.  A popular stream fans out: subscribers
//! registered with `subscribe_stream` receive each
//! `append_stream_fanout` snapshot computed once and delivered N ways
//! through bounded mailboxes (`poll_subscription`).
//! `benches/streaming.rs` measures the incremental-vs-recompute gap,
//! shard scaling, and the coalescing storm.
//!
//! ## Planes
//!
//! The crate keeps two orthogonal planes (DESIGN.md §4):
//! * the **functional plane** computes bit-checked matrix profiles
//!   ([`mp`], [`natsa`], [`runtime`]);
//! * the **timing/energy plane** ([`sim`]) consumes work descriptors from
//!   the functional plane and evaluates per-platform performance, power,
//!   energy and area models to regenerate the paper's tables and figures
//!   ([`report`]).

// Policy: the crate is pure safe Rust (zero `unsafe` today) and stays
// that way — exact FP reproduction plus lock-heavy coordination is
// exactly where a stray `unsafe` would be hardest to audit.  See
// README ("Safety & concurrency checking") and docs/CONCURRENCY.md.
#![forbid(unsafe_code)]

pub mod benchmark;
pub mod coordinator;
pub mod mp;
pub mod natsa;
pub mod prop;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sync;
pub mod timeseries;

/// Crate-wide result type (thin wrapper over [`anyhow`]).
pub type Result<T> = anyhow::Result<T>;

/// Floating-point element trait for the whole stack.
///
/// The paper evaluates double-precision (DP) and single-precision (SP)
/// NATSA designs; every algorithm and model in this crate is generic over
/// this trait so both designs share one implementation.
pub trait Real:
    num_traits::Float
    + num_traits::FromPrimitive
    + num_traits::ToPrimitive
    + std::fmt::Debug
    + std::fmt::Display
    + std::iter::Sum
    + Send
    + Sync
    + 'static
{
    /// Short dtype tag matching the artifact naming scheme ("f32"/"f64").
    const DTYPE: &'static str;
    /// Bytes per element — drives the memory-traffic models in [`sim`].
    const BYTES: usize;
    fn of_f64(v: f64) -> Self {
        num_traits::FromPrimitive::from_f64(v).expect("finite f64 -> Real")
    }
    fn to_f64s(self) -> f64 {
        num_traits::ToPrimitive::to_f64(&self).expect("Real -> f64")
    }
}

impl Real for f32 {
    const DTYPE: &'static str = "f32";
    const BYTES: usize = 4;
}

impl Real for f64 {
    const DTYPE: &'static str = "f64";
    const BYTES: usize = 8;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn real_roundtrip() {
        assert_eq!(f32::DTYPE, "f32");
        assert_eq!(f64::BYTES, 8);
        assert_eq!(f32::of_f64(1.5), 1.5f32);
        assert_eq!(2.5f64.to_f64s(), 2.5);
    }
}
