//! End-to-end driver: arrhythmia detection through the FULL three-layer
//! stack (the repo's headline validation run — see EXPERIMENTS.md §E2E).
//!
//! Pipeline exercised:
//!   synthetic ECG (rust)  →  host stats + diagonal-pair schedule (rust,
//!   Alg. 2)  →  AOT Pallas diag_chunk/dot_init kernels (lowered by
//!   python/compile/aot.py, executed via xla/PJRT)  →  PU-private profile
//!   updates + host reduction (rust)  →  anomaly report,
//! then cross-checked bit-for-bit against the native SCRIMP baseline and
//! the brute-force oracle, in both precisions (the paper's Fig. 12
//! experiment), with the timing/energy models projecting the run onto the
//! paper's platforms.
//!
//! Requires `make artifacts`.  Run:
//!   cargo run --release --example ecg_anomaly

use natsa::coordinator::PjrtEngine;
use natsa::mp::{brute, scrimp, MpConfig};
use natsa::natsa::NatsaConfig;
use natsa::runtime::default_artifact_dir;
use natsa::sim::accel::NatsaDesign;
use natsa::sim::platform::GpPlatform;
use natsa::sim::{Precision, Workload};
use natsa::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

fn main() -> anyhow::Result<()> {
    let n = 4096;
    let m = 64;
    let (t64, ev) = generate_with_event::<f64>(Pattern::EcgLike, n, 5);
    let (start, len) = match ev {
        PlantedEvent::Anomaly { start, len } => (start, len),
        _ => unreachable!(),
    };
    println!("ECG-like series: n={n}, beat anomaly planted at [{start}, {})", start + len);

    // ---- Layer 3 + 2 + 1: PJRT engine over the AOT Pallas kernels (DP).
    let engine = PjrtEngine::<f64>::new(NatsaConfig::default(), default_artifact_dir())
        .with_workers(4);
    let out = engine.compute(&t64, m)?;
    println!(
        "\n[PJRT/AOT DP] {} chunk + {} dot kernel calls on {} workers",
        out.metrics.chunk_calls, out.metrics.dot_calls, out.metrics.workers
    );
    println!(
        "  kernel time {:.2}s, wall {:.2}s, {} cells",
        out.metrics.kernel_seconds, out.metrics.wall_seconds, out.work.cells
    );
    let (discord, dist) = out.profile.discord().unwrap();
    let hit = discord + m >= start && discord < start + len + m;
    println!("  discord at {discord} (d={dist:.3}) -> anomaly {}", if hit { "DETECTED" } else { "MISSED" });
    anyhow::ensure!(hit, "e2e run must detect the planted arrhythmia");

    // ---- Cross-check against native SCRIMP and the brute-force oracle.
    let native = scrimp::matrix_profile(&t64, MpConfig::new(m))?;
    let oracle = brute::matrix_profile(&t64, MpConfig::new(m))?;
    let d_native = out.profile.max_abs_diff(&native);
    let d_oracle = out.profile.max_abs_diff(&oracle);
    println!("\n[validation] max |PJRT - native SCRIMP| = {d_native:.2e}");
    println!("[validation] max |PJRT - brute oracle|  = {d_oracle:.2e}");
    anyhow::ensure!(d_native < 1e-8, "AOT kernels diverged from native");
    anyhow::ensure!(d_oracle < 1e-7, "AOT kernels diverged from the oracle");

    // ---- Fig. 12: single precision detects the same event.
    let t32: Vec<f32> = t64.iter().map(|&x| x as f32).collect();
    let engine_sp = PjrtEngine::<f32>::new(NatsaConfig::default(), default_artifact_dir())
        .with_workers(4);
    let out_sp = engine_sp.compute(&t32, m)?;
    let (discord_sp, dist_sp) = out_sp.profile.discord().unwrap();
    let hit_sp = discord_sp + m >= start && discord_sp < start + len + m;
    println!(
        "\n[PJRT/AOT SP] discord at {discord_sp} (d={dist_sp:.3}) -> anomaly {}",
        if hit_sp { "DETECTED" } else { "MISSED" }
    );
    anyhow::ensure!(hit_sp, "SP run must detect the event too (paper Fig. 12)");

    // ---- Project this workload onto the paper's platforms (Table 2 path).
    println!("\n[projection] modeled time for this workload (n={n}, m={m}):");
    let w = Workload::new(n, m);
    let base = GpPlatform::ddr4_ooo().estimate(&w, Precision::Dp);
    let natsa_dp = NatsaDesign::hbm(Precision::Dp).estimate(&w);
    println!(
        "  DDR4-OoO {:.4}s vs NATSA {:.4}s -> modeled speedup {:.1}x, energy ratio {:.1}x",
        base.time_s,
        natsa_dp.time_s,
        base.time_s / natsa_dp.time_s,
        base.energy_j / natsa_dp.energy_j,
    );
    println!("\nE2E OK: all three layers compose and agree with the oracle.");
    Ok(())
}
