//! Serving demo: many clients submitting time series analysis jobs to a
//! sharded bounded-queue NATSA service (the L3 coordinator as a
//! deployable component: engine shards, workers, backpressure, per-shard
//! + aggregate latency metrics).
//!
//! Run: `cargo run --release --example analysis_service`

use std::sync::Arc;

use natsa::coordinator::service::{shard_of, AnalysisService, ServiceConfig, SubmitError};
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    // 2 shards x 2 workers: the 48-PU fleet is sliced 24 PUs per shard,
    // batch jobs route least-loaded-first and spill when a queue fills.
    let service: Arc<AnalysisService<f64>> = Arc::new(AnalysisService::start_sharded(
        NatsaConfig::default(),
        ServiceConfig::default()
            .with_shards(2)
            .with_workers(2)
            .with_queue_depth(8),
    ));

    // 4 client threads, 6 jobs each, mixed workloads.
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let svc = service.clone();
            std::thread::spawn(move || {
                let mut done = 0;
                let mut rejected = 0;
                for k in 0..6u64 {
                    let pattern = match (c + k) % 3 {
                        0 => Pattern::EcgLike,
                        1 => Pattern::SeismicLike,
                        _ => Pattern::PlantedMotif,
                    };
                    let n = 2048 + 512 * ((c as usize + k as usize) % 4);
                    let series = Arc::new(generate::<f64>(pattern, n, 100 * c + k));
                    // retry loop under backpressure (only hit when EVERY
                    // shard's queue is full)
                    let id = loop {
                        match svc.submit(series.clone(), 64) {
                            Ok(id) => break id,
                            Err(SubmitError::Backpressure) => {
                                rejected += 1;
                                std::thread::sleep(std::time::Duration::from_millis(5));
                            }
                            Err(e) => panic!("{e}"),
                        }
                    };
                    let result = svc.wait(id).expect("result consumed exactly once");
                    let profile = result.profile.expect("job must succeed");
                    let (disc, d) = profile.discord().unwrap();
                    println!(
                        "client {c}: job {id} (shard {}, {} n={n}) -> discord @{disc} d={d:.3} \
                         (wait {:.1}ms, exec {:.1}ms)",
                        shard_of(id),
                        pattern.name(),
                        result.queue_wait_s * 1e3,
                        result.exec_s * 1e3,
                    );
                    done += 1;
                }
                (done, rejected)
            })
        })
        .collect();

    let mut total_done = 0;
    let mut total_retries = 0;
    for c in clients {
        let (done, rejected) = c.join().unwrap();
        total_done += done;
        total_retries += rejected;
    }
    println!("\nall clients done: {total_done} jobs, {total_retries} backpressure retries");
    for k in 0..service.num_shards() {
        println!("shard {k} metrics: {}", service.shard_metrics(k).summary());
    }
    println!("aggregate metrics: {}", service.metrics().summary());
    assert_eq!(total_done, 24);
    assert_eq!(service.metrics().in_flight(), 0);
    assert_eq!(service.retained_results(), 0, "every result was consumed");
}
