//! Serving demo: many clients submitting time series analysis jobs to a
//! bounded-queue NATSA service (the L3 coordinator as a deployable
//! component: workers, backpressure, latency metrics).
//!
//! Run: `cargo run --release --example analysis_service`

use std::sync::Arc;

use natsa::coordinator::service::{AnalysisService, SubmitError};
use natsa::natsa::NatsaConfig;
use natsa::timeseries::generator::{generate, Pattern};

fn main() {
    let service: Arc<AnalysisService<f64>> = Arc::new(AnalysisService::start(
        NatsaConfig::default(),
        /* workers */ 3,
        /* queue depth */ 8,
    ));

    // 4 client threads, 6 jobs each, mixed workloads.
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let svc = service.clone();
            std::thread::spawn(move || {
                let mut done = 0;
                let mut rejected = 0;
                for k in 0..6u64 {
                    let pattern = match (c + k) % 3 {
                        0 => Pattern::EcgLike,
                        1 => Pattern::SeismicLike,
                        _ => Pattern::PlantedMotif,
                    };
                    let n = 2048 + 512 * ((c as usize + k as usize) % 4);
                    let series = Arc::new(generate::<f64>(pattern, n, 100 * c + k));
                    // retry loop under backpressure
                    let id = loop {
                        match svc.submit(series.clone(), 64) {
                            Ok(id) => break id,
                            Err(SubmitError::Backpressure) => {
                                rejected += 1;
                                std::thread::sleep(std::time::Duration::from_millis(5));
                            }
                            Err(e) => panic!("{e}"),
                        }
                    };
                    let result = svc.wait(id);
                    let profile = result.profile.expect("job must succeed");
                    let (disc, d) = profile.discord().unwrap();
                    println!(
                        "client {c}: job {id} ({} n={n}) -> discord @{disc} d={d:.3} \
                         (wait {:.1}ms, exec {:.1}ms)",
                        pattern.name(),
                        result.queue_wait_s * 1e3,
                        result.exec_s * 1e3,
                    );
                    done += 1;
                }
                (done, rejected)
            })
        })
        .collect();

    let mut total_done = 0;
    let mut total_retries = 0;
    for c in clients {
        let (done, rejected) = c.join().unwrap();
        total_done += done;
        total_retries += rejected;
    }
    println!("\nall clients done: {total_done} jobs, {total_retries} backpressure retries");
    println!("service metrics: {}", service.metrics().summary());
    assert_eq!(total_done, 24);
}
