//! Continuous monitoring: the streaming matrix profile on an arriving
//! ECG feed — the workload family the batch API cannot serve (samples
//! arrive forever; recomputing the profile from scratch per sample is
//! O(n²) each time, the STAMPI engine is O(n) per sample and exact).
//!
//! Three stages:
//!   1. direct engine: `NatsaEngine::open_stream`, sample-by-sample, with
//!      live discord tracking that flags the planted arrhythmia online;
//!   2. bounded history: the same feed through a fixed-size window
//!      (O(history) memory — what a device-resident monitor would run);
//!   3. service path: the same stream driven through the
//!      `AnalysisService` job queue (`submit_stream` / `append_stream` /
//!      `snapshot_stream`), the deployment shape.
//!
//! Run: `cargo run --release --example streaming_monitor`

use natsa::coordinator::service::AnalysisService;
use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

fn main() -> anyhow::Result<()> {
    let n = 8192;
    let m = 64;
    let (t, ev) = generate_with_event::<f64>(Pattern::EcgLike, n, 5);
    let (start, len) = match ev {
        PlantedEvent::Anomaly { start, len } => (start, len),
        _ => unreachable!(),
    };
    println!("ECG feed: {n} samples arriving, window m={m}; arrhythmia planted at [{start}, {})", start + len);

    // ---- 1. live engine, sample by sample -------------------------------
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
    let mut session = engine.open_stream(m)?;
    let mut alarm: Option<(usize, usize, f64)> = None; // (sample, window, dist)
    for (s, &x) in t.iter().enumerate() {
        session.append(x);
        // check the live discord once per "beat" of samples
        if s % 96 == 0 && s > 2 * m {
            if let Some((w, d)) = session.profile().discord() {
                // an online alarm: the discord distance jumps when the
                // anomalous beat has fully streamed in
                if d > 6.0 && alarm.is_none() {
                    alarm = Some((s, w, d));
                }
            }
        }
    }
    let profile = session.profile();
    let (discord, dist) = profile.discord().expect("profile non-empty");
    let hit = discord + m >= start && discord < start + len + m;
    println!(
        "\n[live] {} windows, {} cells on {} PUs (imbalance {:.4})",
        profile.len(),
        session.work().cells,
        session.pu_cells().len(),
        session.imbalance()
    );
    if let Some((s, w, d)) = alarm {
        println!("[live] online alarm at sample {s}: window {w}, distance {d:.3}");
    }
    println!("[live] final discord: window {discord} (d={dist:.3}) -> anomaly {}", if hit { "DETECTED" } else { "MISSED" });
    anyhow::ensure!(hit, "streaming monitor must detect the planted arrhythmia");

    // ---- 2. bounded history (device-resident shape) ---------------------
    let history = 2048;
    let mut bounded = engine.open_stream_bounded(m, Some(history))?;
    for &x in &t {
        bounded.append(x);
    }
    let bp = bounded.profile();
    println!(
        "\n[bounded] history {history} samples -> {} live windows (first abs window {})",
        bp.len(),
        bounded.first_window()
    );

    // ---- 3. the service path (deployment shape) -------------------------
    let service: AnalysisService<f64> = AnalysisService::start(NatsaConfig::default(), 2, 16);
    let stream = service
        .submit_stream(m, None)
        .map_err(|e| anyhow::anyhow!("submit_stream: {e}"))?;
    let mut final_snapshot = None;
    for packet in t.chunks(256) {
        // a device shipping 256-sample packets through the job queue,
        // awaiting each ack (ordering + backpressure handled naturally)
        let id = service
            .append_stream(stream, packet)
            .map_err(|e| anyhow::anyhow!("append_stream: {e}"))?;
        let snap = service
            .wait(id)
            .map_err(|e| anyhow::anyhow!("wait: {e}"))?
            .profile
            .map_err(|e| anyhow::anyhow!("append failed: {e}"))?;
        final_snapshot = Some(snap);
    }
    let final_snapshot = final_snapshot.expect("at least one packet");
    let d_service = final_snapshot.max_abs_diff(&profile);
    println!(
        "\n[service] {} append jobs done | snapshot vs live engine: max diff {d_service:.2e}",
        service.metrics().jobs_completed.load(std::sync::atomic::Ordering::Relaxed)
    );
    anyhow::ensure!(d_service < 1e-9, "service stream diverged from direct engine");
    println!("[service] metrics: {}", service.metrics().summary());
    service.close_stream(stream);
    service.shutdown();

    println!("\nstreaming monitor OK: exact profile maintained under append end to end.");
    Ok(())
}
