//! Quickstart: compute a matrix profile with NATSA and find the anomaly.
//!
//! Reproduces the paper's Fig. 1 scenario end to end on the functional
//! engine, then — if `make artifacts` has been run — also executes the
//! self-contained AOT `mp_tile` kernel through PJRT to show the compiled
//! path producing the same answer.
//!
//! Run: `cargo run --release --example quickstart`

use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::runtime::{default_artifact_dir, Runtime};
use natsa::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

fn main() -> anyhow::Result<()> {
    // 1. A periodic signal with a planted anomaly (the paper's Fig. 1).
    let n = 4096;
    let m = 64;
    let (t, event) = generate_with_event::<f64>(Pattern::SineWithAnomaly, n, 7);
    let (start, len) = match event {
        PlantedEvent::Anomaly { start, len } => (start, len),
        _ => unreachable!(),
    };
    println!("series: n={n}, window m={m}, planted anomaly at [{start}, {})", start + len);

    // 2. NATSA: Algorithm 2 over 48 PUs (functional engine).
    let engine = NatsaEngine::<f64>::new(NatsaConfig::default());
    let out = engine.compute(&t, m)?;
    let (discord, dist) = out.profile.discord().expect("profile non-empty");
    println!(
        "NATSA: {} cells on {} PUs (imbalance {:.3})",
        out.work.cells,
        out.pu_cells.len(),
        out.schedule_imbalance
    );
    println!("discord (most anomalous window): index {discord}, distance {dist:.3}");
    let hit = discord + m >= start && discord < start + len + m;
    println!("anomaly detected: {}", if hit { "YES" } else { "NO" });
    assert!(hit, "quickstart must find the planted anomaly");

    // 3. Same math through the AOT-compiled Pallas kernel (PJRT), if the
    //    artifacts are built.  The mp_tile artifact is fixed at n=1024.
    match Runtime::new(&default_artifact_dir()) {
        Ok(rt) => {
            let (t1k, _) = generate_with_event::<f32>(Pattern::SineWithAnomaly, 1024, 7);
            let (p, _i) = rt.mp_tile(&t1k)?;
            let nw = 1024 - 64 + 1; // artifact was lowered with m=64
            let (peak, val) = p[..nw]
                .iter()
                .enumerate()
                .filter(|(_, d)| d.is_finite())
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            println!("PJRT mp_tile (AOT Pallas, n=1024): discord at {peak} (d={val:.3})");
        }
        Err(e) => {
            println!("(PJRT path skipped: {e}; run `make artifacts`)");
        }
    }
    Ok(())
}
