//! Design-space exploration (paper Section 6.3): sweep PU counts and
//! memory technologies, cross-check the closed-form model against the
//! chunk-level discrete-event simulation, and print the balance analysis
//! that selects 48 PUs for HBM (and 8 for DDR4, footnote 2).
//!
//! Run: `cargo run --release --example design_space`

use natsa::benchmark::Table;
use natsa::sim::accel::{design_space, NatsaDesign};
use natsa::sim::dram::DramConfig;
use natsa::sim::{Precision, Workload};

fn main() {
    let w = Workload::new(524_288, 256); // rand_512K, the paper's pivot

    for (prec, label) in [(Precision::Dp, "DP"), (Precision::Sp, "SP")] {
        let mut table = Table::new(&["PUs", "time(s)", "bound", "BW-util", "area mm^2", "peak W"]);
        for p in design_space(prec, DramConfig::hbm2(), &[8, 16, 24, 32, 48, 64, 96, 128], &w) {
            table.row(&[
                p.pus.to_string(),
                format!("{:.2}", p.time_s),
                p.bound.to_string(),
                format!("{:.0}%", p.bw_utilization * 100.0),
                format!("{:.1}", p.area_mm2),
                format!("{:.2}", p.peak_power_w),
            ]);
        }
        table.print(&format!("HBM design space, {label}, rand_512K"));
    }

    // Closed form vs discrete-event simulation at the chosen point.
    let mut table = Table::new(&["design", "closed-form(s)", "DES(s)", "delta", "DES events"]);
    for (label, d) in [
        ("NATSA-DP 48PU/HBM", NatsaDesign::hbm(Precision::Dp)),
        ("NATSA-SP 48PU/HBM", NatsaDesign::hbm(Precision::Sp)),
        ("NATSA-DP 8PU/DDR4", NatsaDesign::ddr4(Precision::Dp)),
    ] {
        let cf = d.estimate(&w);
        let (des, events) = d.simulate(&w, None);
        table.row(&[
            label.to_string(),
            format!("{:.2}", cf.time_s),
            format!("{:.2}", des.time_s),
            format!("{:+.1}%", (des.time_s / cf.time_s - 1.0) * 100.0),
            events.to_string(),
        ]);
    }
    table.print("closed-form vs chunk-level DES");

    // The balance argument, in numbers.
    let d = NatsaDesign::hbm(Precision::Dp);
    println!(
        "\nper-PU demand {:.2} GB/s vs share {:.2} GB/s at 48 PUs -> balanced;",
        d.demand_per_pu_gbs(),
        d.bw_per_pu_gbs()
    );
    println!(
        "paper: 48 PUs balanced, 32 compute-bound, 64 memory-bound; DDR4 saturates at 8 PUs."
    );
}
