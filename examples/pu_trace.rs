//! PU datapath trace (paper Section 4.1 / Fig. 5): execute diagonals
//! through the functional PU state machine and print the pipeline-stage
//! occupancy (DPU / DPUU / DCU / PUU) plus the per-chunk cycle and
//! DRAM-traffic accounting the Aladdin-substitute model consumes.
//!
//! Run: `cargo run --release --example pu_trace`

use natsa::benchmark::Table;
use natsa::mp::MatrixProfile;
use natsa::natsa::pu::{ChunkWork, PuDatapath, PuDesign};
use natsa::prop::Rng;
use natsa::timeseries::sliding_stats;

fn main() {
    let n = 2048;
    let m = 64;
    let mut rng = Rng::new(3);
    let t: Vec<f64> = rng.gauss_vec(n);
    let st = sliding_stats(&t, m);
    let nw = st.len();
    let excl = m / 4;

    for (label, design) in [("PU-DP", PuDesign::dp()), ("PU-SP", PuDesign::sp())] {
        let dp = PuDatapath::new(design, &t, &st);
        let mut profile = MatrixProfile::new_inf(nw, m, excl);
        let mut table = Table::new(&[
            "diagonal", "cells", "DPU cyc", "DPUU cyc", "DCU cyc", "PUU cyc", "model cyc", "DRAM B",
        ]);
        for d in [excl, nw / 4, nw / 2, nw - 64] {
            let (trace, work) = dp.run_diagonal(d, &mut profile);
            let chunk = ChunkWork { cells: work.cells, first_dot: true, m };
            table.row(&[
                d.to_string(),
                work.cells.to_string(),
                trace.dpu_cycles.to_string(),
                trace.dpuu_cycles.to_string(),
                trace.dcu_cycles.to_string(),
                trace.puu_cycles.to_string(),
                chunk.cycles(&design).to_string(),
                chunk.traffic_bytes(&design).to_string(),
            ]);
        }
        table.print(&format!(
            "{label}: lanes={}, {} FP mults / {} adds, {} regs, {} B scratchpad",
            design.lanes, design.fp_mults, design.fp_adds, design.registers,
            design.scratchpad_bytes
        ));
    }
    println!(
        "\nThe six-step execution flow of Section 4.1: one DPU burst per\n\
         diagonal, then DPUU->DCU->PUU pipelined groups of `lanes` cells."
    );
}
