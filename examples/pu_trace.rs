//! PU datapath trace (paper Section 4.1 / Fig. 5): execute band tiles
//! and single diagonals through the functional PU state machine and
//! print the pipeline-stage occupancy (DPU / DPUU / DCU / PUU) plus the
//! per-chunk cycle and DRAM-traffic accounting the Aladdin-substitute
//! model consumes.  The trace total and the descriptor model charge the
//! SAME closed-form cycles (`PuTrace::cycles == ChunkWork::cycles`) —
//! the "model cyc" column is printed from the descriptor to show it.
//!
//! Run: `cargo run --release --example pu_trace`

use natsa::benchmark::Table;
use natsa::mp::kernel::BAND;
use natsa::mp::MatrixProfile;
use natsa::natsa::pu::{ChunkWork, PuDatapath, PuDesign};
use natsa::natsa::scheduler::BandTile;
use natsa::prop::Rng;
use natsa::timeseries::sliding_stats;

fn main() {
    let n = 2048;
    let m = 64;
    let mut rng = Rng::new(3);
    let t: Vec<f64> = rng.gauss_vec(n);
    let st = sliding_stats(&t, m);
    let nw = st.len();
    let excl = m / 4;

    for (label, design) in [("PU-DP", PuDesign::dp()), ("PU-SP", PuDesign::sp())] {
        let dp = PuDatapath::new(design, &t, &st);
        let mut profile = MatrixProfile::new_inf(nw, m, excl);
        let mut table = Table::new(&[
            "tile", "width", "cells", "DPU cyc", "DPUU cyc", "DCU cyc", "PUU cyc",
            "trace cyc", "model cyc", "DRAM B",
        ]);
        for tile in [
            BandTile { d0: excl, width: BAND },
            BandTile { d0: nw / 4, width: BAND },
            BandTile { d0: nw / 2, width: 4 },
            BandTile { d0: nw - 64, width: 1 },
        ] {
            let (trace, work) = dp.run_band(tile, &mut profile);
            let chunk = ChunkWork {
                cells: work.cells,
                first_dots: tile.width as u64,
                m,
            };
            assert_eq!(trace.cycles(), chunk.cycles(&design), "models diverged");
            table.row(&[
                format!("{}..{}", tile.d0, tile.d0 + tile.width),
                tile.width.to_string(),
                work.cells.to_string(),
                trace.dpu_cycles.to_string(),
                trace.dpuu_cycles.to_string(),
                trace.dcu_cycles.to_string(),
                trace.puu_cycles.to_string(),
                trace.cycles().to_string(),
                chunk.cycles(&design).to_string(),
                chunk.traffic_bytes(&design).to_string(),
            ]);
        }
        table.print(&format!(
            "{label}: lanes={}, {} FP mults / {} adds, {} regs, {} B scratchpad",
            design.lanes, design.fp_mults, design.fp_adds, design.registers,
            design.scratchpad_bytes
        ));
    }
    println!(
        "\nThe six-step execution flow of Section 4.1 over band tiles: one\n\
         DPU burst per diagonal the tile begins, then DPUU->DCU->PUU\n\
         pipelined groups of `lanes` cells at II=1 across the whole tile."
    );
}
