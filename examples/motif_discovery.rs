//! Motif discovery workflow: preprocessing → fast approximate pass
//! (PreSCRIMP) → exact NATSA run → top-k ranked events.
//!
//! The shape of a real analysis session from the paper's §1 application
//! list: repair a gappy recording, detrend it, get an interactive-speed
//! approximate answer, then confirm with the exact engine and extract the
//! ranked motif/discord report.
//!
//! Run: `cargo run --release --example motif_discovery`

use natsa::benchmark::Table;
use natsa::mp::{prescrimp, topk, MpConfig};
use natsa::natsa::{NatsaConfig, NatsaEngine};
use natsa::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};
use natsa::timeseries::transform::{detrend, repair_gaps, standardize};

fn main() -> anyhow::Result<()> {
    // A "field recording": planted motif + drift + sensor dropouts.
    let n = 8192;
    let m = 64;
    let (mut t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, n, 21);
    let (a, b, mlen) = match ev {
        PlantedEvent::Motif { a, b, len } => (a, b, len),
        _ => unreachable!(),
    };
    for (i, v) in t.iter_mut().enumerate() {
        *v += 0.002 * i as f64; // slow drift
    }
    for gap in [500usize, 3000, 7777] {
        for k in 0..5 {
            t[gap + k] = f64::NAN; // dropouts
        }
    }

    // 1. preprocessing
    let mut t = repair_gaps(&t)?;
    detrend(&mut t);
    standardize(&mut t);
    println!("preprocessed: n={n}, gaps repaired, detrended, standardized");

    // 2. interactive pass: PreSCRIMP (O(n^2/s) work)
    let t0 = std::time::Instant::now();
    let (approx, work) = prescrimp::matrix_profile(&t, MpConfig::new(m), None, 9)?;
    let (mi, md) = approx.motif().unwrap();
    println!(
        "\nPreSCRIMP ({} cells, {:.0} ms): best motif so far @{mi} d={md:.4}",
        work.cells,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // 3. exact pass: NATSA engine
    let t0 = std::time::Instant::now();
    let exact = NatsaEngine::<f64>::new(NatsaConfig::default()).compute(&t, m)?;
    println!(
        "NATSA exact ({} cells, {:.0} ms)",
        exact.work.cells,
        t0.elapsed().as_secs_f64() * 1e3
    );

    // PreSCRIMP must upper-bound the exact profile
    let worst = approx
        .p
        .iter()
        .zip(&exact.profile.p)
        .map(|(ap, ex)| ex - ap)
        .fold(f64::NEG_INFINITY, f64::max);
    println!("approx-vs-exact: max(exact - approx) = {worst:.2e} (<= 0 means upper bound)");

    // 4. ranked report
    let mut table = Table::new(&["rank", "kind", "window", "neighbor", "distance"]);
    for (r, ev) in topk::top_motifs(&exact.profile, 3).iter().enumerate() {
        table.row(&[
            (r + 1).to_string(),
            "motif".into(),
            ev.index.to_string(),
            ev.neighbor.to_string(),
            format!("{:.4}", ev.distance),
        ]);
    }
    for (r, ev) in topk::top_discords(&exact.profile, 3).iter().enumerate() {
        table.row(&[
            (r + 1).to_string(),
            "discord".into(),
            ev.index.to_string(),
            ev.neighbor.to_string(),
            format!("{:.4}", ev.distance),
        ]);
    }
    table.print("top-k events");

    // the planted segment is longer than m, so every window inside it is
    // an exact repeat: rank-1 must fall within either copy's span
    let top = topk::top_motifs(&exact.profile, 1)[0];
    let inside = |x: usize, s: usize| x >= s && x + m <= s + mlen;
    anyhow::ensure!(
        inside(top.index, a) || inside(top.index, b),
        "rank-1 motif at {} outside planted spans [{a},+{mlen}) / [{b},+{mlen})",
        top.index
    );
    println!("\nplanted motif pair ({a}, {b}) recovered as rank-1 ✓");
    Ok(())
}
