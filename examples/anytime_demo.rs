//! Anytime property demo (paper Sections 1 / 4.2): interrupt NATSA at
//! increasing work budgets and watch the planted motif emerge long before
//! full coverage — but only when diagonals are visited in random order.
//!
//! Run: `cargo run --release --example anytime_demo`

use natsa::benchmark::Table;
use natsa::natsa::anytime::{run_anytime, Budget};
use natsa::natsa::{NatsaConfig, Order};
use natsa::timeseries::generator::{generate_with_event, Pattern, PlantedEvent};

fn main() -> anyhow::Result<()> {
    let n = 8192;
    let m = 64;
    let (t, ev) = generate_with_event::<f64>(Pattern::PlantedMotif, n, 11);
    let (a, b) = match ev {
        PlantedEvent::Motif { a, b, .. } => (a, b),
        _ => unreachable!(),
    };
    println!("planted motif pair at windows {a} and {b} (n={n}, m={m})");

    for (order, label) in [
        (Order::Random(123), "random order (anytime preserved)"),
        (Order::Sequential, "sequential order (anytime forfeited)"),
    ] {
        let config = NatsaConfig::default().with_order(order);
        let mut table = Table::new(&["budget", "progress", "best motif d", "found pair?"]);
        for pct in [2, 5, 10, 25, 50, 100] {
            let out = run_anytime(&t, m, &config, Budget::Fraction(pct as f64 / 100.0))?;
            let (mi, md) = out.profile.motif().unwrap();
            let found = md < 1e-6 && (mi == a || mi == b);
            table.row(&[
                format!("{pct}%"),
                format!("{:.1}%", out.progress * 100.0),
                format!("{md:.4}"),
                if found { "YES".into() } else { "no".into() },
            ]);
        }
        table.print(label);
    }
    println!(
        "\nRandom order finds the motif at a small fraction of the work;\n\
         sequential order only discovers events up to the interruption\n\
         point (the trade-off Section 4.2 describes)."
    );
    Ok(())
}
